//! Thread-per-actor execution engine (paper §III.D): "each actor that has
//! been mapped for execution on a CPU core is instantiated as a separate
//! thread, and actor data exchange over FIFOs is synchronized by mutex
//! primitives".
//!
//! Firing rule: an actor fires when every input port has atr(p) tokens
//! available (data-driven); production blocks on full output FIFOs
//! (backpressure).  Device heterogeneity is simulated by the CoreSet
//! semaphore + per-actor cost padding (see `device.rs`); end-of-stream
//! propagates by closing FIFOs in both directions.

use crate::dataflow::{AppGraph, EdgeId, Token, TokenPool};
use crate::runtime::device::{pad_to_target, CoreSet, DeviceModel};
use crate::runtime::fifo::Fifo;
use crate::runtime::kernels::{ActorKernel, FireOutcome};
use crate::runtime::metrics::{Metrics, RunReport};
use crate::runtime::trace::{self, Stage};
use crate::dataflow::rates::AtrCell;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

pub struct Engine {
    graph: AppGraph,
    device: DeviceModel,
    fifos: Vec<Arc<Fifo>>,
    atrs: Vec<AtrCell>,
    flops: BTreeMap<String, u64>,
    pool: Option<TokenPool>,
}

impl Engine {
    pub fn new(graph: AppGraph, device: DeviceModel) -> Result<Self> {
        graph.validate().map_err(|e| anyhow!("{e}"))?;
        let mut fifos = Vec::with_capacity(graph.edges.len());
        let mut atrs = Vec::with_capacity(graph.edges.len());
        for e in &graph.edges {
            let f = Arc::new(Fifo::new(e.capacity));
            if e.initial_tokens > 0 {
                let tokens = (0..e.initial_tokens)
                    .map(|i| Token::new(vec![0u8; e.token_bytes], i as u64))
                    .collect();
                f.preload(tokens);
            }
            fifos.push(f);
            let rate = graph.actors[e.src.actor.0].out_ports[e.src.port].rate;
            atrs.push(AtrCell::new(rate));
        }
        Ok(Engine { graph, device, fifos, atrs, flops: BTreeMap::new(), pool: None })
    }

    /// Shared active-token-rate cell of an edge (CA kernels hold clones).
    pub fn atr(&self, edge: EdgeId) -> AtrCell {
        self.atrs[edge.0].clone()
    }

    /// Attach per-actor FLOPs estimates (cost-model fallback).
    pub fn set_flops(&mut self, flops: BTreeMap<String, u64>) {
        self.flops = flops;
    }

    /// Attach a token buffer pool: every actor thread hands the
    /// payloads of consumed (unshared) tokens back to `pool`, and
    /// pool-aware kernels draw their output buffers from the same pool,
    /// so a steady-state pipeline circulates a fixed set of buffers.
    pub fn set_token_pool(&mut self, pool: TokenPool) {
        self.pool = Some(pool);
    }

    pub fn graph(&self) -> &AppGraph {
        &self.graph
    }

    /// Run to completion: sources fire until Stop, the wave drains through
    /// the pipeline, and the engine joins all actor threads.
    pub fn run(self, mut kernels: BTreeMap<String, Box<dyn ActorKernel>>) -> Result<RunReport> {
        let metrics = Arc::new(Metrics::new());
        let cores = Arc::new(CoreSet::new(self.device.cores));
        // Compute actors serialize through the device's accelerator queue
        // (the paper's GPU executes DNN layers one at a time); TX/RX FIFO
        // endpoints are CPU-side and bypass it, so communication overlaps
        // compute on multicore devices.
        let accel = Arc::new(CoreSet::new(self.device.accel_slots.min(1 << 20)));
        let mut handles = Vec::new();
        let t_start = Instant::now();

        for (ai, actor) in self.graph.actors.iter().enumerate() {
            let name = actor.name.clone();
            let kernel = kernels
                .remove(&name)
                .ok_or_else(|| anyhow!("no kernel bound for actor {name}"))?;

            // In-port FIFOs ordered by port index.
            let mut ins: Vec<(Arc<Fifo>, AtrCell)> = Vec::new();
            {
                let mut with_port: Vec<(usize, Arc<Fifo>, AtrCell)> = self
                    .graph
                    .edges
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.dst.actor.0 == ai)
                    .map(|(ei, e)| (e.dst.port, self.fifos[ei].clone(), self.atrs[ei].clone()))
                    .collect();
                with_port.sort_by_key(|(p, _, _)| *p);
                for (_, f, a) in with_port {
                    ins.push((f, a));
                }
            }
            // Out-port FIFOs ordered by port index.
            let mut outs: Vec<Arc<Fifo>> = Vec::new();
            {
                let mut with_port: Vec<(usize, Arc<Fifo>)> = self
                    .graph
                    .edges
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.src.actor.0 == ai)
                    .map(|(ei, e)| (e.src.port, self.fifos[ei].clone()))
                    .collect();
                with_port.sort_by_key(|(p, _)| *p);
                for (_, f) in with_port {
                    outs.push(f);
                }
            }

            let metrics = metrics.clone();
            let cores = cores.clone();
            let pool = self.pool.clone();
            let is_io = name.starts_with("__tx") || name.starts_with("__rx");
            let accel = (!is_io).then(|| accel.clone());
            // With padding off the cost model is calibration-only: the
            // firing is the real kernel, nothing else.
            let target_ms = if self.device.padding {
                self.device.target_ms(&name, self.flops.get(&name).copied().unwrap_or(0))
            } else {
                0.0
            };
            let handle = std::thread::Builder::new()
                .name(format!("actor-{name}"))
                .spawn(move || {
                    actor_loop(name, kernel, ins, outs, cores, accel, target_ms, pool, metrics)
                })
                .map_err(|e| anyhow!("spawn: {e}"))?;
            handles.push(handle);
        }

        let mut first_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => first_err = first_err.or(Some(anyhow!("actor thread panicked"))),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }

        let wall = t_start.elapsed();
        let stats = metrics.snapshot();
        // Frames = max firings over structural sinks (incl. TX endpoints).
        let frames = self
            .graph
            .actors
            .iter()
            .filter(|a| a.is_sink())
            .filter_map(|a| stats.get(&a.name).map(|s| s.firings))
            .max()
            .unwrap_or(0);
        Ok(RunReport { device: self.device.name.clone(), wall, frames, actors: stats })
    }
}

#[allow(clippy::too_many_arguments)]
fn actor_loop(
    name: String,
    kernel: Box<dyn ActorKernel>,
    ins: Vec<(Arc<Fifo>, AtrCell)>,
    outs: Vec<Arc<Fifo>>,
    cores: Arc<CoreSet>,
    accel: Option<Arc<CoreSet>>,
    target_ms: f64,
    pool: Option<TokenPool>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    let result =
        actor_loop_inner(&name, kernel, &ins, &outs, cores, accel, target_ms, pool, metrics);
    // End of stream OR error: signal both directions so peers wind down
    // instead of blocking forever on a dead actor's FIFOs.
    for (fifo, _) in &ins {
        fifo.close();
    }
    for fifo in &outs {
        fifo.close();
    }
    result
}

#[allow(clippy::too_many_arguments)]
fn actor_loop_inner(
    name: &str,
    mut kernel: Box<dyn ActorKernel>,
    ins: &[(Arc<Fifo>, AtrCell)],
    outs: &[Arc<Fifo>],
    cores: Arc<CoreSet>,
    accel: Option<Arc<CoreSet>>,
    target_ms: f64,
    pool: Option<TokenPool>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    let mut seq: u64 = 0;
    'run: loop {
        // 1. Gather inputs (blocks; None on upstream close).
        let t_in = Instant::now();
        let mut inputs: Vec<Vec<Token>> = Vec::with_capacity(ins.len());
        for (fifo, atr) in ins {
            let n = atr.get() as usize;
            match fifo.pop_n(n) {
                Some(tokens) => inputs.push(tokens),
                None => break 'run,
            }
        }
        let blocked_in = t_in.elapsed();

        // 2. Fire under a core permit (+ the accelerator queue for compute
        //    actors), padded to the device cost model.  Lock order is
        //    always core -> accel, so the two semaphores cannot deadlock.
        let outcome = {
            let _core = cores.acquire();
            let _accel = accel.as_ref().map(|a| a.acquire());
            // Process-local flight-recorder span: one per firing, on the
            // actor's own thread (the recorder carries the thread name).
            let _fire = trace::span(trace::LOCAL, 0, Stage::ActorFire, seq as u32);
            let t_fire = Instant::now();
            let outcome = kernel.fire(&inputs, seq)?;
            pad_to_target(t_fire.elapsed(), target_ms);
            outcome
        };
        let busy = t_in.elapsed() - blocked_in;

        // 3. Emit outputs (blocks on backpressure; false on consumer gone).
        let t_out = Instant::now();
        match outcome {
            FireOutcome::Stop => break 'run,
            FireOutcome::Produced(port_payloads) => {
                anyhow::ensure!(
                    port_payloads.len() == outs.len(),
                    "{}: produced {} ports, graph has {}",
                    name,
                    port_payloads.len(),
                    outs.len()
                );
                for (port, payloads) in port_payloads.into_iter().enumerate() {
                    for p in payloads {
                        if !outs[port].push(Token::new(p, seq)) {
                            metrics.record(name, busy, blocked_in, t_out.elapsed());
                            break 'run;
                        }
                    }
                }
            }
        }
        // Consumed tokens go back to the buffer pool (unless a branch
        // edge still shares the payload) for producing kernels to reuse.
        if let Some(pool) = &pool {
            for port in inputs {
                for t in port {
                    pool.recycle(t);
                }
            }
        }
        metrics.record(name, busy, blocked_in, t_out.elapsed());
        seq = seq.wrapping_add(1);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{ActorKind, ActorSpec, AppGraph, RateSpec};
    use crate::runtime::kernels::{MapKernel, SinkKernel, SourceKernel};
    use std::sync::atomic::{AtomicU64, Ordering};

    fn kmap(
        entries: Vec<(&str, Box<dyn ActorKernel>)>,
    ) -> BTreeMap<String, Box<dyn ActorKernel>> {
        entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
    }

    #[test]
    fn chain_pipeline_runs_all_frames() {
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let mid = g.add_spa("mid");
        let snk = g.add_spa("snk");
        g.connect(src, mid, 8, 2);
        g.connect(mid, snk, 8, 2);
        let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
        let n = Arc::new(AtomicU64::new(0));
        let report = engine
            .run(kmap(vec![
                ("src", Box::new(SourceKernel::new(10, 8, 1, 1))),
                ("mid", Box::new(MapKernel { f: |b: &[u8]| b.to_vec(), out_ports: 1 })),
                ("snk", Box::new(SinkKernel::new(n.clone()))),
            ]))
            .unwrap();
        assert_eq!(n.load(Ordering::Relaxed), 10);
        assert_eq!(report.frames, 10);
        assert_eq!(report.actors["mid"].firings, 10);
    }

    #[test]
    fn branch_and_join_graph() {
        // src -> a -> join <- b <- src (diamond): both branches carry every
        // frame; join concatenates.
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        let join = g.add_spa("join");
        let snk = g.add_spa("snk");
        g.connect(src, a, 4, 2);
        g.connect(src, b, 4, 2);
        g.connect(a, join, 4, 2);
        g.connect(b, join, 4, 2);
        g.connect(join, snk, 8, 2);
        let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
        let n = Arc::new(AtomicU64::new(0));
        let report = engine
            .run(kmap(vec![
                ("src", Box::new(SourceKernel::new(5, 4, 2, 2))),
                ("a", Box::new(MapKernel { f: |b: &[u8]| b.to_vec(), out_ports: 1 })),
                ("b", Box::new(MapKernel { f: |b: &[u8]| b.to_vec(), out_ports: 1 })),
                ("join", Box::new(crate::runtime::kernels::ConcatKernel { out_ports: 1 })),
                ("snk", Box::new(SinkKernel::new(n.clone()))),
            ]))
            .unwrap();
        assert_eq!(report.frames, 5);
        assert_eq!(report.actors["join"].firings, 5);
    }

    #[test]
    fn missing_kernel_is_an_error() {
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let snk = g.add_spa("snk");
        g.connect(src, snk, 4, 2);
        let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
        let err = engine
            .run(kmap(vec![("src", Box::new(SourceKernel::new(1, 4, 1, 3)))]))
            .unwrap_err();
        assert!(err.to_string().contains("no kernel bound"));
    }

    #[test]
    fn device_cost_padding_slows_pipeline() {
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let snk = g.add_spa("snk");
        g.connect(src, snk, 4, 2);
        let device = DeviceModel::native("slow").with_cost("src", 5.0);
        let engine = Engine::new(g, device).unwrap();
        let n = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        let report = engine
            .run(kmap(vec![
                ("src", Box::new(SourceKernel::new(10, 4, 1, 4))),
                ("snk", Box::new(SinkKernel::new(n.clone()))),
            ]))
            .unwrap();
        assert!(t0.elapsed().as_millis() >= 50, "padding not applied");
        assert!(report.ms_per_frame() >= 5.0);
    }

    #[test]
    fn no_pad_device_ignores_cost_table() {
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let snk = g.add_spa("snk");
        g.connect(src, snk, 4, 2);
        let device = DeviceModel::native("fast").with_cost("src", 50.0).with_padding(false);
        let engine = Engine::new(g, device).unwrap();
        let n = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        engine
            .run(kmap(vec![
                ("src", Box::new(SourceKernel::new(4, 4, 1, 4))),
                ("snk", Box::new(SinkKernel::new(n))),
            ]))
            .unwrap();
        assert!(
            t0.elapsed().as_millis() < 100,
            "padding applied despite --no-pad: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn token_pool_recycles_consumed_payloads() {
        use crate::dataflow::TokenPool;
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let mid = g.add_spa("mid");
        let snk = g.add_spa("snk");
        g.connect(src, mid, 8, 2);
        g.connect(mid, snk, 8, 2);
        let mut engine = Engine::new(g, DeviceModel::native("host")).unwrap();
        let pool = TokenPool::new(64);
        engine.set_token_pool(pool.clone());
        let n = Arc::new(AtomicU64::new(0));
        engine
            .run(kmap(vec![
                ("src", Box::new(SourceKernel::new(10, 8, 1, 1))),
                ("mid", Box::new(MapKernel { f: |b: &[u8]| b.to_vec(), out_ports: 1 })),
                ("snk", Box::new(SinkKernel::new(n)))
            ]))
            .unwrap();
        // Every consumed token was unshared: 10 at mid + 10 at snk.
        assert_eq!(pool.stats().recycled, 20);
        assert_eq!(pool.stats().shared_drops, 0);
    }

    #[test]
    fn single_core_serializes_two_actors() {
        // Two 5 ms actors on 1 core: 10 frames take >= ~100 ms; on 2+
        // cores the pipeline overlaps them (~50 ms + fill).
        let build = |cores: usize| {
            let mut g = AppGraph::new();
            let src = g.add_spa("src");
            let mid = g.add_spa("mid");
            let snk = g.add_spa("snk");
            g.connect(src, mid, 4, 2);
            g.connect(mid, snk, 4, 2);
            let mut device = DeviceModel::native("d").with_cost("src", 5.0).with_cost("mid", 5.0);
            device.cores = cores;
            let engine = Engine::new(g, device).unwrap();
            let n = Arc::new(AtomicU64::new(0));
            let t0 = Instant::now();
            engine
                .run(kmap(vec![
                    ("src", Box::new(SourceKernel::new(10, 4, 1, 5))),
                    ("mid", Box::new(MapKernel { f: |b: &[u8]| b.to_vec(), out_ports: 1 })),
                    ("snk", Box::new(SinkKernel::new(n))),
                ]))
                .unwrap();
            t0.elapsed().as_secs_f64() * 1e3
        };
        let serial = build(1);
        let parallel = build(4);
        assert!(serial >= 95.0, "serial {serial} ms");
        assert!(parallel <= serial * 0.8, "parallel {parallel} vs serial {serial}");
    }

    #[test]
    fn variable_rate_downsampler() {
        // DPG-style: source at rate 1, consumer pops atr=2 per firing
        // (paired frames), so 10 frames -> 5 firings downstream.
        let mut g = AppGraph::new();
        let src = g.add_actor(ActorSpec::new("src", ActorKind::Da).in_dpg(0));
        let pair = g.add_actor(ActorSpec::new("pair", ActorKind::Dpa).in_dpg(0));
        let snk = g.add_spa("snk");
        g.connect_rated(src, pair, 4, 8, RateSpec::variable(1, 2), 0);
        g.connect(pair, snk, 8, 4);
        let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
        // atr defaults to url = 2.
        let n = Arc::new(AtomicU64::new(0));
        struct PairKernel;
        impl ActorKernel for PairKernel {
            fn fire(&mut self, inputs: &[Vec<Token>], _s: u64) -> Result<FireOutcome> {
                assert_eq!(inputs[0].len(), 2, "atr=2 consumption");
                let mut out = inputs[0][0].data.to_vec();
                out.extend_from_slice(&inputs[0][1].data);
                Ok(FireOutcome::one_each(vec![out]))
            }
        }
        struct RatedSource(u64, u64);
        impl ActorKernel for RatedSource {
            fn fire(&mut self, _i: &[Vec<Token>], _s: u64) -> Result<FireOutcome> {
                if self.0 >= self.1 {
                    return Ok(FireOutcome::Stop);
                }
                self.0 += 1;
                // Produce 2 tokens per firing to match atr=2 on the edge.
                Ok(FireOutcome::Produced(vec![vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]]]))
            }
        }
        let report = engine
            .run(kmap(vec![
                ("src", Box::new(RatedSource(0, 5))),
                ("pair", Box::new(PairKernel)),
                ("snk", Box::new(SinkKernel::new(n.clone()))),
            ]))
            .unwrap();
        assert_eq!(report.actors["pair"].firings, 5);
        assert_eq!(n.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn feedback_edge_with_initial_token() {
        // src -> acc, acc -> acc (state, 1 initial token), acc -> snk.
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let acc = g.add_spa("acc");
        let snk = g.add_spa("snk");
        g.connect(src, acc, 4, 2);
        g.connect_rated(acc, acc, 4, 2, RateSpec::fixed(1), 1);
        g.connect(acc, snk, 4, 2);
        struct AccKernel;
        impl ActorKernel for AccKernel {
            fn fire(&mut self, inputs: &[Vec<Token>], _s: u64) -> Result<FireOutcome> {
                // port order: in0 = from src, in1 = state.
                let x = inputs[0][0].data[0];
                let state = inputs[1][0].data[0];
                let new_state = state.wrapping_add(x);
                Ok(FireOutcome::one_each(vec![
                    vec![new_state; 4], // to self (state out is port 0: edge order)
                    vec![new_state; 4],
                ]))
            }
        }
        let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
        let n = Arc::new(AtomicU64::new(0));
        let report = engine
            .run(kmap(vec![
                ("src", Box::new(SourceKernel::new(4, 4, 1, 6))),
                ("acc", Box::new(AccKernel)),
                ("snk", Box::new(SinkKernel::new(n.clone()))),
            ]))
            .unwrap();
        assert_eq!(report.actors["acc"].firings, 4);
    }
}
