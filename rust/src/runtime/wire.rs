//! Compact activation wire format: the codec that lets partition-point
//! activations cross the device-edge link as int8 (with a scale header)
//! or fp16 instead of raw f32, cutting link bytes ~4x / ~2x per
//! inference (the DEFER / 2-Step-Pruning observation that transmission
//! size at the split dominates constrained links).
//!
//! Four dtypes:
//!
//! * **f32** — the legacy format: raw little-endian f32 bytes, exactly
//!   the protocol-v2 payload.  Always supported; the transparent
//!   fallback when either peer lacks the codec.
//! * **f16** — IEEE 754 binary16, round-to-nearest-even.  2 bytes per
//!   element, no header.
//! * **i8** — symmetric per-tensor quantization (zero-point 0): a 4-byte
//!   f32 scale header followed by one `i8` per element, where
//!   `scale = max|x| / 127` and `q = clamp(round(x / scale), -127, 127)`.
//!   1 byte per element; the -128 code is never produced, which is also
//!   what keeps the int8 GEMM's paired i16 products overflow-free.
//! * **sparse-i8** — top-k magnitude selection stacked on the i8
//!   quantizer (the 2-Step-Pruning observation: the activation tensor
//!   at the split point is heavily prunable).  Per tensor the encoder
//!   keeps the [`SPARSE_KEEP_DIV`]-th largest |q| codes (ties resolved
//!   by a deterministic per-frame histogram threshold), then ships them
//!   under whichever index form is cheapest for THIS tensor — bitmap,
//!   run-length, or a dense-i8 fallback — so the encoded size never
//!   exceeds dense i8 plus the [`SPARSE_HEADER_BYTES`]-byte header.
//!   See [`encode_activation`] for the frame layout.
//!
//! **Determinism contract:** `decode(encode(x))` is a pure function of
//! the bytes, identical on every host (round-to-nearest-even for f16,
//! round-half-away-from-zero for i8).  The serving model exploits this:
//! the client runs its local stages, encodes, *decodes its own payload
//! back* and continues the chain from the decoded tensor — so client
//! and server compute bit-identical digests at any wire dtype, and the
//! loadgen's byte-for-byte response verification keeps working with
//! quantization on.
//!
//! Negotiation: a protocol-v3 handshake carries a capability byte
//! ([`CAP_I8`] | [`CAP_F16`]); the server intersects it with its own
//! enabled set and replies with the chosen dtype (plus the server's
//! compute [`Precision`]).  v2 peers carry no capability byte and get
//! f32 frames, byte-identical to the old protocol — see
//! `server::protocol`.

use anyhow::{bail, Result};

/// Capability bit: peer can encode/decode int8 activations.
pub const CAP_I8: u8 = 1;
/// Capability bit: peer can encode/decode fp16 activations.
pub const CAP_F16: u8 = 2;
/// Capability bit: peer can send/accept flight-recorder span context
/// (`[u64 trace_id][u32 parent_span]`) ahead of traced inference
/// payloads — see `runtime::trace` and `server::protocol`.  Orthogonal
/// to dtype negotiation: [`negotiate`] ignores it.
pub const CAP_TRACE: u8 = 4;
/// Capability bit: peer can encode/decode sparse-i8 activations (top-k
/// magnitude selection over the i8 quantizer with a bitmap/run-length
/// index).  Implies [`CAP_I8`] | [`CAP_F16`] on the advertising side so
/// a downgrade against an older peer always lands on a shared dtype.
pub const CAP_SPARSE_I8: u8 = 8;
/// Capability bit: peer understands fleet session migration — it can
/// follow a MIGRATE redirect hint (client side) or accept EXPORT/IMPORT
/// session-image frames (server side).  Like [`CAP_TRACE`] it is
/// orthogonal to dtype negotiation: [`negotiate`] ignores it, and a
/// peer that lacks it simply downgrades to plain reconnect.
pub const CAP_MIGRATE: u8 = 16;
/// Capability bit: peer understands end-to-end deadline propagation —
/// it can send `[u32 budget_ms][u8 priority]` ahead of deadline-infer
/// payloads and accept the `SHED` / `DEADLINE_EXCEEDED` response codes
/// of the overload control plane.  Like [`CAP_TRACE`] and
/// [`CAP_MIGRATE`] it is orthogonal to dtype negotiation: [`negotiate`]
/// ignores it, and against an older peer the budget is silently dropped
/// (plain infer frames, overload expressed as `rejected`).
pub const CAP_DEADLINE: u8 = 32;

/// Element type of activations on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireDtype {
    #[default]
    F32,
    F16,
    I8,
    /// Top-k sparse selection over i8 codes; variable-length,
    /// self-describing payload (see [`encode_activation`]).
    SparseI8,
}

impl WireDtype {
    pub fn as_str(self) -> &'static str {
        match self {
            WireDtype::F32 => "f32",
            WireDtype::F16 => "f16",
            WireDtype::I8 => "int8",
            WireDtype::SparseI8 => "sparse",
        }
    }

    pub fn parse(s: &str) -> Result<WireDtype> {
        match s {
            "f32" => Ok(WireDtype::F32),
            "f16" => Ok(WireDtype::F16),
            "int8" | "i8" => Ok(WireDtype::I8),
            "sparse" | "sparse-int8" | "sparse-i8" => Ok(WireDtype::SparseI8),
            v => bail!("unknown wire dtype {v} (f32|f16|int8|sparse)"),
        }
    }

    pub fn bytes_per_elem(self) -> usize {
        match self {
            WireDtype::F32 => 4,
            WireDtype::F16 => 2,
            // Sparse ships at most one code byte per element (the dense
            // fallback); its true per-tensor size is data-dependent.
            WireDtype::I8 | WireDtype::SparseI8 => 1,
        }
    }

    /// Fixed per-payload header (the i8 scale; the sparse form byte +
    /// scale + element count).
    pub fn header_bytes(self) -> usize {
        match self {
            WireDtype::I8 => 4,
            WireDtype::SparseI8 => SPARSE_HEADER_BYTES,
            _ => 0,
        }
    }

    /// Wire byte of the handshake reply.
    pub fn to_u8(self) -> u8 {
        match self {
            WireDtype::F32 => 0,
            WireDtype::F16 => 1,
            WireDtype::I8 => 2,
            WireDtype::SparseI8 => 3,
        }
    }

    pub fn from_u8(b: u8) -> Result<WireDtype> {
        match b {
            0 => Ok(WireDtype::F32),
            1 => Ok(WireDtype::F16),
            2 => Ok(WireDtype::I8),
            3 => Ok(WireDtype::SparseI8),
            v => bail!("bad wire dtype byte {v}"),
        }
    }

    /// The capability bits a client advertising this dtype sends (each
    /// dtype also implies everything cheaper to decode, so a downgrade
    /// never fails).
    pub fn caps(self) -> u8 {
        match self {
            WireDtype::F32 => 0,
            WireDtype::F16 => CAP_F16,
            WireDtype::I8 => CAP_I8 | CAP_F16,
            WireDtype::SparseI8 => CAP_SPARSE_I8 | CAP_I8 | CAP_F16,
        }
    }
}

/// Compute precision of the DNN kernels behind a plan (the
/// `--precision` knob): f32 reference kernels or the int8 GEMM/matvec
/// path with per-channel weight scales.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "int8" | "i8" => Ok(Precision::Int8),
            v => bail!("unknown precision {v} (f32|int8)"),
        }
    }

    pub fn to_u8(self) -> u8 {
        match self {
            Precision::F32 => 0,
            Precision::Int8 => 1,
        }
    }

    pub fn from_u8(b: u8) -> Result<Precision> {
        match b {
            0 => Ok(Precision::F32),
            1 => Ok(Precision::Int8),
            v => bail!("bad precision byte {v}"),
        }
    }
}

/// What one serving session negotiated: the activation wire dtype and
/// the compute precision both sides run the stage chain at.  Client and
/// server must agree on both for the digest to stay bit-exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionCodec {
    pub wire: WireDtype,
    pub precision: Precision,
}

impl SessionCodec {
    /// The legacy contract: raw f32 on the wire, f32 compute.
    pub fn f32() -> SessionCodec {
        SessionCodec::default()
    }
}

/// Server-side negotiation: the best dtype both the client's capability
/// bits and the server's enabled set allow (sparse-i8 > i8 > f16 > f32
/// — smallest expected wire wins).
pub fn negotiate(client_caps: u8, server_caps: u8) -> WireDtype {
    let both = client_caps & server_caps;
    if both & CAP_SPARSE_I8 != 0 {
        WireDtype::SparseI8
    } else if both & CAP_I8 != 0 {
        WireDtype::I8
    } else if both & CAP_F16 != 0 {
        WireDtype::F16
    } else {
        WireDtype::F32
    }
}

/// Encoded payload size for `elems` activation elements.  For the
/// variable-length sparse dtype this is the dense-fallback **ceiling**
/// — the size the encoder guarantees never to exceed; use
/// [`sparse_expected_len`] with a calibrated density for the expected
/// size.
pub fn encoded_len(dtype: WireDtype, elems: usize) -> usize {
    dtype.header_bytes() + elems * dtype.bytes_per_elem()
}

/// Encoded payload size when every payload of this dtype has one fixed
/// length per element count — `None` for the data-dependent sparse
/// dtype (validate those by decoding; the payload is self-describing).
pub fn fixed_encoded_len(dtype: WireDtype, elems: usize) -> Option<usize> {
    match dtype {
        WireDtype::SparseI8 => None,
        _ => Some(encoded_len(dtype, elems)),
    }
}

/// Element count implied by an encoded payload length (`None` when the
/// length is not a whole number of elements for this dtype, and always
/// for the sparse dtype, whose length alone does not determine it —
/// see [`sparse_stats`]).
pub fn decoded_elems(dtype: WireDtype, payload_len: usize) -> Option<usize> {
    if dtype == WireDtype::SparseI8 {
        return None;
    }
    let body = payload_len.checked_sub(dtype.header_bytes())?;
    let per = dtype.bytes_per_elem();
    (body % per == 0).then_some(body / per)
}

/// f32-equivalent byte count of an encoded payload (what the same
/// tensor would have cost in the legacy format) — the numerator of the
/// wire-compression-ratio gauge.  Length-only; cannot price a sparse
/// payload (use [`f32_equiv_bytes`] where the bytes are at hand).
pub fn f32_equiv_len(dtype: WireDtype, payload_len: usize) -> usize {
    match decoded_elems(dtype, payload_len) {
        Some(elems) => elems * 4,
        None => payload_len,
    }
}

/// f32-equivalent byte count of an encoded payload, sparse included
/// (the element count comes out of the sparse header).  Unparseable
/// payloads count 1:1, like ragged ones in [`f32_equiv_len`].
pub fn f32_equiv_bytes(dtype: WireDtype, payload: &[u8]) -> usize {
    match dtype {
        WireDtype::SparseI8 => match sparse_stats(payload) {
            Some(st) => st.elems * 4,
            None => payload.len(),
        },
        _ => f32_equiv_len(dtype, payload.len()),
    }
}

// ---------------------------------------------------------- sparse i8
//
// Payload layout (dtype is known from negotiation, the rest is
// self-describing):
//
//   [u8 form][f32 scale][u32 n]                     -- 9-byte header
//   form 0 (dense fallback):  n i8 codes
//   form 1 (bitmap index):    ceil(n/8) bitmap bytes, then one i8 code
//                             per set bit, in ascending index order
//   form 2 (run-length):      [u32 k], then k x ([u8 gap][i8 code]);
//                             cursor += gap, out[cursor] = code,
//                             cursor += 1 — gaps > 255 are bridged by
//                             (255, 0) pad entries
//
// The encoder quantizes exactly like the i8 dtype, keeps only the top
// n/SPARSE_KEEP_DIV codes by magnitude (deterministic per-frame
// histogram threshold over |q|), then emits whichever form is smallest
// for this tensor — so the total never exceeds the dense-i8 body plus
// the 9-byte header, and an all-zero tensor costs 13 bytes.

/// Sparse payload header: form byte + f32 scale + u32 element count.
pub const SPARSE_HEADER_BYTES: usize = 9;
/// Top-k keep fraction: the encoder ships at most `n / SPARSE_KEEP_DIV`
/// coefficients per tensor (the largest |q|; natural zeros never ship).
/// 4 targets ≥75% sparsity — bitmap-indexed, that is ≥2.4x below dense
/// i8 — while the synthetic model's digest stays within the bench-gated
/// epsilon (see `benches/sparse_wire.rs`).
pub const SPARSE_KEEP_DIV: usize = 4;

const SPARSE_FORM_DENSE: u8 = 0;
const SPARSE_FORM_BITMAP: u8 = 1;
const SPARSE_FORM_RLE: u8 = 2;

/// What a sparse payload header + index section declare (parse-only;
/// no code bytes are touched).  `None` if the payload is not a
/// structurally valid sparse frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparseStats {
    /// Decoded element count.
    pub elems: usize,
    /// Coefficients shipped (dense fallback counts every element).
    pub nnz: usize,
}

/// Parse a sparse payload's header and index structure without
/// decoding values.  Validates exactly what [`decode_activation_into`]
/// validates, so `Some` here means the payload will decode cleanly
/// into an `elems`-long tensor.
pub fn sparse_stats(payload: &[u8]) -> Option<SparseStats> {
    if payload.len() < SPARSE_HEADER_BYTES {
        return None;
    }
    let form = payload[0];
    let n = u32::from_le_bytes(payload[5..9].try_into().ok()?) as usize;
    let body = &payload[SPARSE_HEADER_BYTES..];
    match form {
        SPARSE_FORM_DENSE => (body.len() == n).then_some(SparseStats { elems: n, nnz: n }),
        SPARSE_FORM_BITMAP => {
            let bm_len = n.div_ceil(8);
            if body.len() < bm_len {
                return None;
            }
            let (bitmap, codes) = body.split_at(bm_len);
            // Stray bits past n would be out-of-bounds indices.
            let tail_bits = n % 8;
            if tail_bits != 0 && bitmap[bm_len - 1] >> tail_bits != 0 {
                return None;
            }
            let nnz: usize = bitmap.iter().map(|b| b.count_ones() as usize).sum();
            (codes.len() == nnz).then_some(SparseStats { elems: n, nnz })
        }
        SPARSE_FORM_RLE => {
            if body.len() < 4 {
                return None;
            }
            let k = u32::from_le_bytes(body[..4].try_into().ok()?) as usize;
            if body.len() != 4 + k.checked_mul(2)? {
                return None;
            }
            // Every entry advances the cursor by gap + 1; the final
            // cursor must stay within n (out-of-bounds index check).
            let mut cursor = 0usize;
            for entry in body[4..].chunks_exact(2) {
                cursor += entry[0] as usize + 1;
                if cursor > n {
                    return None;
                }
            }
            Some(SparseStats { elems: n, nnz: k })
        }
        _ => None,
    }
}

/// Expected sparse-encoded size for an `elems`-long tensor at a
/// calibrated coefficient density (the cost model the Explorer prices
/// link bytes with): header + cheapest index form at that density,
/// never above the dense fallback.
pub fn sparse_expected_len(elems: usize, density: f64) -> usize {
    let nnz = ((elems as f64) * density.clamp(0.0, 1.0)).ceil() as usize;
    let bitmap = elems.div_ceil(8) + nnz;
    let rle = 4 + 2 * nnz;
    SPARSE_HEADER_BYTES + bitmap.min(rle).min(elems)
}

/// Deterministic per-frame top-k threshold: the smallest `t` such that
/// at most `n / SPARSE_KEEP_DIV` codes satisfy `|q| > t`.  Returns
/// `(t, kept)`.  A histogram pass over |q| — O(n), no allocation.
fn sparse_threshold(x: &[f32], inv_scale: f32) -> (u8, usize) {
    let mut hist = [0u32; 128];
    for v in x {
        let q = crate::runtime::linalg::quantize_one(*v, inv_scale);
        hist[q.unsigned_abs() as usize] += 1;
    }
    let target = (x.len() / SPARSE_KEEP_DIV).max(1);
    // count(t) = how many codes have |q| > t; walk t upward until the
    // kept set fits the budget (t = 126 always does: only |q| = 127
    // survives it, and clamping guarantees nothing exceeds 127).
    let mut above: usize = hist[1..].iter().map(|&c| c as usize).sum();
    let mut t = 0u8;
    while above > target && t < 126 {
        t += 1;
        above -= hist[t as usize] as usize;
    }
    (t, above)
}

/// RLE entry count for the kept set (pads included), plus the bitmap
/// cost, computed in one pass so the encoder can pick the cheaper form
/// before writing anything.
fn sparse_rle_entries(x: &[f32], inv_scale: f32, t: u8) -> usize {
    let mut entries = 0usize;
    let mut prev_end = 0usize; // index after the last kept element
    for (i, v) in x.iter().enumerate() {
        let q = crate::runtime::linalg::quantize_one(*v, inv_scale);
        if q.unsigned_abs() > t {
            let gap = i - prev_end;
            entries += gap / 256 + 1; // (255, 0) pads bridge long gaps
            prev_end = i + 1;
        }
    }
    entries
}

fn encode_sparse(x: &[f32], out: &mut Vec<u8>) {
    let scale = crate::runtime::linalg::quant_scale(x);
    let n = x.len();
    let (t, nnz, rle_entries) = if scale == 0.0 {
        (127u8, 0usize, 0usize)
    } else {
        let inv = 1.0 / scale;
        let (t, nnz) = sparse_threshold(x, inv);
        (t, nnz, sparse_rle_entries(x, inv, t))
    };
    let bitmap_cost = n.div_ceil(8) + nnz;
    let rle_cost = 4 + 2 * rle_entries;
    let dense_cost = n;
    let (form, _cost) = [
        (SPARSE_FORM_RLE, rle_cost),
        (SPARSE_FORM_BITMAP, bitmap_cost),
        (SPARSE_FORM_DENSE, dense_cost),
    ]
    .into_iter()
    .min_by_key(|&(_, c)| c)
    .unwrap();
    out.clear();
    out.push(form);
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    let inv = if scale == 0.0 { 0.0 } else { 1.0 / scale };
    let keep = |v: f32| -> i8 {
        let q = crate::runtime::linalg::quantize_one(v, inv);
        if q.unsigned_abs() > t {
            q
        } else {
            0
        }
    };
    match form {
        SPARSE_FORM_DENSE => {
            for v in x {
                out.push(keep(*v) as u8);
            }
        }
        SPARSE_FORM_BITMAP => {
            let bm_start = out.len();
            out.resize(bm_start + n.div_ceil(8), 0);
            for (i, v) in x.iter().enumerate() {
                let q = keep(*v);
                if q != 0 {
                    out[bm_start + i / 8] |= 1 << (i % 8);
                }
            }
            for v in x {
                let q = keep(*v);
                if q != 0 {
                    out.push(q as u8);
                }
            }
        }
        _ => {
            out.extend_from_slice(&(rle_entries as u32).to_le_bytes());
            let mut prev_end = 0usize;
            for (i, v) in x.iter().enumerate() {
                let q = keep(*v);
                if q != 0 {
                    let mut gap = i - prev_end;
                    while gap > 255 {
                        out.push(255);
                        out.push(0);
                        gap -= 256;
                    }
                    out.push(gap as u8);
                    out.push(q as u8);
                    prev_end = i + 1;
                }
            }
        }
    }
}

/// Decode a sparse payload into `x` (zero-filled first, then kept
/// coefficients scattered).  Strict: every structural violation —
/// truncated index, stray bitmap bits past `n`, an RLE cursor running
/// off the tensor, a wrong element count — is an error, never a panic
/// or an out-of-bounds write.
fn decode_sparse_into(payload: &[u8], x: &mut [f32]) -> Result<()> {
    let Some(st) = sparse_stats(payload) else {
        bail!("malformed sparse payload of {} bytes", payload.len());
    };
    if st.elems != x.len() {
        bail!("sparse payload decodes {} elements, expected {}", st.elems, x.len());
    }
    let scale = f32::from_le_bytes(payload[1..5].try_into().unwrap());
    x.fill(0.0);
    let body = &payload[SPARSE_HEADER_BYTES..];
    match payload[0] {
        SPARSE_FORM_DENSE => {
            for (dst, &b) in x.iter_mut().zip(body) {
                *dst = (b as i8) as f32 * scale;
            }
        }
        SPARSE_FORM_BITMAP => {
            let bm_len = x.len().div_ceil(8);
            let (bitmap, codes) = body.split_at(bm_len);
            let mut next = 0usize;
            for (i, dst) in x.iter_mut().enumerate() {
                if bitmap[i / 8] >> (i % 8) & 1 != 0 {
                    *dst = (codes[next] as i8) as f32 * scale;
                    next += 1;
                }
            }
        }
        _ => {
            let mut cursor = 0usize;
            for entry in body[4..].chunks_exact(2) {
                cursor += entry[0] as usize;
                x[cursor] = (entry[1] as i8) as f32 * scale;
                cursor += 1;
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- f16

/// f32 -> IEEE binary16 bits, round-to-nearest-even (overflow to inf,
/// NaN payload preserved in the top mantissa bits and quieted).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xff) as i32;
    let man = b & 0x007f_ffff;
    if exp == 255 {
        // Inf / NaN: keep NaN-ness explicit (quiet bit 9).
        let nan = if man != 0 { 0x0200 | ((man >> 13) as u16 & 0x03ff) } else { 0 };
        return sign | 0x7c00 | nan;
    }
    let e = exp - 112; // rebias 127 -> 15
    if e >= 31 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> signed zero
        }
        // Subnormal half: shift the (implicit-bit) mantissa into place.
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let mut t = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && t & 1 == 1) {
            t += 1; // may round up to the smallest normal: still correct
        }
        return sign | t as u16;
    }
    let mut t = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && t & 1 == 1) {
        t += 1; // mantissa carry rolls into the exponent (up to inf)
    }
    sign | t as u16
}

/// IEEE binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // Subnormal: renormalize into an f32 exponent.
            let mut e = 113u32; // 127 - 14
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// --------------------------------------------------------------- codec

/// Encode an activation tensor into `out` (cleared, reused across
/// frames — no allocation once its capacity is warm).
pub fn encode_activation(dtype: WireDtype, x: &[f32], out: &mut Vec<u8>) {
    let _span = crate::runtime::trace::span_current(
        crate::runtime::trace::Stage::WireEncode,
        x.len() as u32,
    );
    if dtype == WireDtype::F32 {
        // The canonical raw-f32 serializer (clears + reuses `out`).
        crate::util::tensor::f32_extend_bytes(x, out);
        return;
    }
    out.clear();
    out.reserve(encoded_len(dtype, x.len()));
    match dtype {
        WireDtype::F32 => unreachable!("handled above"),
        WireDtype::SparseI8 => encode_sparse(x, out),
        WireDtype::F16 => {
            for v in x {
                out.extend_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
            }
        }
        WireDtype::I8 => {
            let scale = crate::runtime::linalg::quant_scale(x);
            out.extend_from_slice(&scale.to_le_bytes());
            if scale == 0.0 {
                out.resize(4 + x.len(), 0);
            } else {
                // The same quantizer step as the int8 compute path —
                // one definition, one determinism contract.
                let inv = 1.0 / scale;
                for v in x {
                    out.push(crate::runtime::linalg::quantize_one(*v, inv) as u8);
                }
            }
        }
    }
}

/// Decode an encoded activation into a caller-owned f32 slice whose
/// length fixes the expected element count.  Allocation-free.
pub fn decode_activation_into(dtype: WireDtype, payload: &[u8], x: &mut [f32]) -> Result<()> {
    let _span = crate::runtime::trace::span_current(
        crate::runtime::trace::Stage::WireDecode,
        x.len() as u32,
    );
    if dtype == WireDtype::SparseI8 {
        return decode_sparse_into(payload, x);
    }
    if decoded_elems(dtype, payload.len()) != Some(x.len()) {
        bail!(
            "{} payload of {} bytes does not decode to {} elements (expect {})",
            dtype.as_str(),
            payload.len(),
            x.len(),
            encoded_len(dtype, x.len())
        );
    }
    match dtype {
        WireDtype::F32 => {
            match crate::util::tensor::cast_f32_slice(payload) {
                Some(vals) => x.copy_from_slice(vals),
                None => {
                    for (dst, chunk) in x.iter_mut().zip(payload.chunks_exact(4)) {
                        *dst = f32::from_le_bytes(chunk.try_into().unwrap());
                    }
                }
            }
        }
        WireDtype::F16 => {
            for (dst, chunk) in x.iter_mut().zip(payload.chunks_exact(2)) {
                *dst = f16_bits_to_f32(u16::from_le_bytes(chunk.try_into().unwrap()));
            }
        }
        WireDtype::I8 => {
            let scale = f32::from_le_bytes(payload[..4].try_into().unwrap());
            for (dst, &b) in x.iter_mut().zip(&payload[4..]) {
                *dst = (b as i8) as f32 * scale;
            }
        }
        WireDtype::SparseI8 => unreachable!("handled above"),
    }
    Ok(())
}

/// Decode into raw little-endian f32 bytes (the legacy token payload
/// layout) — what an RX FIFO hands downstream actors.  `out` is
/// cleared and reused.
pub fn decode_to_f32_bytes(dtype: WireDtype, payload: &[u8], out: &mut Vec<u8>) -> Result<()> {
    if dtype == WireDtype::SparseI8 {
        let Some(st) = sparse_stats(payload) else {
            bail!("malformed sparse payload of {} bytes", payload.len());
        };
        let mut vals = vec![0.0f32; st.elems];
        decode_sparse_into(payload, &mut vals)?;
        out.clear();
        out.reserve(st.elems * 4);
        for v in &vals {
            out.extend_from_slice(&v.to_le_bytes());
        }
        return Ok(());
    }
    let Some(elems) = decoded_elems(dtype, payload.len()) else {
        bail!("{} payload of {} bytes is ragged", dtype.as_str(), payload.len());
    };
    out.clear();
    out.reserve(elems * 4);
    match dtype {
        WireDtype::F32 => out.extend_from_slice(payload),
        WireDtype::F16 => {
            for chunk in payload.chunks_exact(2) {
                let v = f16_bits_to_f32(u16::from_le_bytes(chunk.try_into().unwrap()));
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        WireDtype::I8 => {
            let scale = f32::from_le_bytes(payload[..4].try_into().unwrap());
            for &b in &payload[4..] {
                let v = (b as i8) as f32 * scale;
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        WireDtype::SparseI8 => unreachable!("handled above"),
    }
    Ok(())
}

/// Encode raw little-endian f32 token bytes (must be a whole number of
/// f32s) — the TX-FIFO-side counterpart of [`decode_to_f32_bytes`].
pub fn encode_f32_bytes(dtype: WireDtype, f32_bytes: &[u8], out: &mut Vec<u8>) -> Result<()> {
    if f32_bytes.len() % 4 != 0 {
        bail!("token of {} bytes is not an f32 tensor", f32_bytes.len());
    }
    if dtype == WireDtype::F32 {
        out.clear();
        out.extend_from_slice(f32_bytes);
        return Ok(());
    }
    match crate::util::tensor::cast_f32_slice(f32_bytes) {
        Some(vals) => encode_activation(dtype, vals, out),
        None => {
            let vals = crate::util::tensor::bytes_to_f32(f32_bytes);
            encode_activation(dtype, &vals, out);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn negotiation_prefers_smallest_wire() {
        let server = CAP_I8 | CAP_F16;
        assert_eq!(negotiate(WireDtype::I8.caps(), server), WireDtype::I8);
        assert_eq!(negotiate(WireDtype::F16.caps(), server), WireDtype::F16);
        assert_eq!(negotiate(0, server), WireDtype::F32);
        // Server with the codec disabled downgrades everyone.
        assert_eq!(negotiate(WireDtype::I8.caps(), 0), WireDtype::F32);
        // i8-capable server without f16 still meets an f16-only client at f32.
        assert_eq!(negotiate(CAP_F16, CAP_I8), WireDtype::F32);
        // Sparse wins when both sides have it; an old peer on either
        // side silently lands on the best shared dense dtype.
        let sparse_server = CAP_SPARSE_I8 | CAP_I8 | CAP_F16;
        assert_eq!(negotiate(WireDtype::SparseI8.caps(), sparse_server), WireDtype::SparseI8);
        assert_eq!(negotiate(WireDtype::SparseI8.caps(), server), WireDtype::I8);
        assert_eq!(negotiate(WireDtype::I8.caps(), sparse_server), WireDtype::I8);
        assert_eq!(negotiate(WireDtype::SparseI8.caps(), 0), WireDtype::F32);
    }

    #[test]
    fn dtype_bytes_round_trip() {
        for d in [WireDtype::F32, WireDtype::F16, WireDtype::I8, WireDtype::SparseI8] {
            assert_eq!(WireDtype::from_u8(d.to_u8()).unwrap(), d);
            assert_eq!(WireDtype::parse(d.as_str()).unwrap(), d);
        }
        assert!(WireDtype::from_u8(9).is_err());
        for p in [Precision::F32, Precision::Int8] {
            assert_eq!(Precision::from_u8(p.to_u8()).unwrap(), p);
            assert_eq!(Precision::parse(p.as_str()).unwrap(), p);
        }
    }

    #[test]
    fn f16_known_values_are_exact() {
        // Exactly representable values survive the round trip bitwise.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 1.5, 2.0, 65504.0, -65504.0] {
            let h = f32_to_f16_bits(v);
            assert_eq!(f16_bits_to_f32(h), v, "{v}");
        }
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        // Overflow saturates to inf; tiny values flush to zero.
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xfc00);
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn f16_subnormals_round_trip() {
        // Smallest positive subnormal half = 2^-24.
        let tiny = f16_bits_to_f32(0x0001);
        assert_eq!(tiny, 2.0f32.powi(-24));
        assert_eq!(f32_to_f16_bits(tiny), 0x0001);
        // Largest subnormal and smallest normal.
        assert_eq!(f32_to_f16_bits(f16_bits_to_f32(0x03ff)), 0x03ff);
        assert_eq!(f32_to_f16_bits(f16_bits_to_f32(0x0400)), 0x0400);
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 sits exactly between 1.0 and the next half (1 + 2^-10):
        // ties go to the even mantissa (1.0).
        let tie = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie), 0x3c00);
        // Just above the tie rounds up.
        let above = 1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20);
        assert_eq!(f32_to_f16_bits(above), 0x3c01);
    }

    #[test]
    fn f16_error_is_bounded() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..1000 {
            let v = rng.f32_range(-1.5, 1.5);
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            // Relative error <= 2^-11 for normal halves.
            assert!((r - v).abs() <= v.abs() * 4.9e-4 + 1e-7, "{v} -> {r}");
        }
    }

    #[test]
    fn i8_codec_round_trips_within_scale() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 21.0).collect();
        let mut enc = Vec::new();
        encode_activation(WireDtype::I8, &x, &mut enc);
        assert_eq!(enc.len(), encoded_len(WireDtype::I8, x.len()));
        let mut dec = vec![0.0f32; x.len()];
        decode_activation_into(WireDtype::I8, &enc, &mut dec).unwrap();
        let scale = f32::from_le_bytes(enc[..4].try_into().unwrap());
        for (a, b) in x.iter().zip(&dec) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-7, "{a} vs {b}");
        }
        // The extreme value is exact (it defines the scale).
        let mx = x.iter().cloned().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(dec.iter().any(|v| (v.abs() - mx).abs() < scale * 0.5));
    }

    #[test]
    fn i8_all_zero_tensor_encodes_scale_zero() {
        let x = [0.0f32; 8];
        let mut enc = Vec::new();
        encode_activation(WireDtype::I8, &x, &mut enc);
        assert_eq!(f32::from_le_bytes(enc[..4].try_into().unwrap()), 0.0);
        let mut dec = [1.0f32; 8];
        decode_activation_into(WireDtype::I8, &enc, &mut dec).unwrap();
        assert_eq!(dec, [0.0f32; 8]);
    }

    #[test]
    fn codec_is_idempotent_after_one_round_trip() {
        // decode(encode(x)) is a fixed point: encoding the decoded tensor
        // again reproduces the same bytes — the property that makes the
        // client's "decode your own payload" trick give bit-exact
        // client/server agreement.
        let mut rng = crate::util::rng::Rng::new(11);
        let x: Vec<f32> = (0..256).map(|_| rng.f32_range(-1.5, 1.5)).collect();
        for dtype in [WireDtype::F16, WireDtype::I8, WireDtype::SparseI8] {
            let mut e1 = Vec::new();
            encode_activation(dtype, &x, &mut e1);
            let mut d1 = vec![0.0f32; x.len()];
            decode_activation_into(dtype, &e1, &mut d1).unwrap();
            let mut e2 = Vec::new();
            encode_activation(dtype, &d1, &mut e2);
            let mut d2 = vec![0.0f32; x.len()];
            decode_activation_into(dtype, &e2, &mut d2).unwrap();
            assert_eq!(d1, d2, "{dtype:?} round trip not idempotent");
        }
    }

    #[test]
    fn f32_bytes_paths_agree_with_slice_paths() {
        let x = [0.25f32, -1.0, 3.5, 0.0];
        let raw = crate::util::tensor::f32_to_bytes(&x);
        for dtype in [WireDtype::F32, WireDtype::F16, WireDtype::I8, WireDtype::SparseI8] {
            let mut enc_a = Vec::new();
            encode_activation(dtype, &x, &mut enc_a);
            let mut enc_b = Vec::new();
            encode_f32_bytes(dtype, &raw, &mut enc_b).unwrap();
            assert_eq!(enc_a, enc_b, "{dtype:?}");
            let mut back = Vec::new();
            decode_to_f32_bytes(dtype, &enc_a, &mut back).unwrap();
            let mut direct = vec![0.0f32; x.len()];
            decode_activation_into(dtype, &enc_a, &mut direct).unwrap();
            assert_eq!(back, crate::util::tensor::f32_to_bytes(&direct), "{dtype:?}");
        }
        assert!(encode_f32_bytes(WireDtype::I8, &raw[..5], &mut Vec::new()).is_err());
    }

    #[test]
    fn decode_rejects_ragged_payloads() {
        let mut x = [0.0f32; 4];
        assert!(decode_activation_into(WireDtype::F32, &[0u8; 15], &mut x).is_err());
        assert!(decode_activation_into(WireDtype::F16, &[0u8; 7], &mut x).is_err());
        assert!(decode_activation_into(WireDtype::I8, &[0u8; 3], &mut x).is_err());
        // Right shape, wrong element count.
        assert!(decode_activation_into(WireDtype::I8, &[0u8; 4 + 5], &mut x).is_err());
        assert_eq!(decoded_elems(WireDtype::I8, 4 + 4), Some(4));
        assert_eq!(decoded_elems(WireDtype::I8, 2), None);
    }

    #[test]
    fn equivalent_length_math() {
        assert_eq!(encoded_len(WireDtype::F32, 1024), 4096);
        assert_eq!(encoded_len(WireDtype::F16, 1024), 2048);
        assert_eq!(encoded_len(WireDtype::I8, 1024), 1028);
        assert_eq!(f32_equiv_len(WireDtype::I8, 1028), 4096);
        assert_eq!(f32_equiv_len(WireDtype::F16, 2048), 4096);
        assert_eq!(f32_equiv_len(WireDtype::F32, 4096), 4096);
        // Sparse is data-dependent: no fixed length, no length-only
        // equivalence — the self-describing header carries the count.
        assert_eq!(fixed_encoded_len(WireDtype::SparseI8, 1024), None);
        assert_eq!(fixed_encoded_len(WireDtype::I8, 1024), Some(1028));
        assert_eq!(decoded_elems(WireDtype::SparseI8, 393), None);
        let x: Vec<f32> = (0..1024).map(|i| (i as f32).sin()).collect();
        let mut enc = Vec::new();
        encode_activation(WireDtype::SparseI8, &x, &mut enc);
        assert_eq!(f32_equiv_bytes(WireDtype::SparseI8, &enc), 4096);
        assert_eq!(f32_equiv_bytes(WireDtype::I8, &[0u8; 1028]), 4096);
        // At the top-k density (1/4), bitmap-indexed sparse prices well
        // under dense i8 — the Explorer's expected-bytes model.
        let expected = sparse_expected_len(1024, 0.25);
        assert_eq!(expected, SPARSE_HEADER_BYTES + 1024 / 8 + 256);
        assert!((encoded_len(WireDtype::I8, 1024) as f64) / (expected as f64) > 2.0);
        // Degenerate densities stay within the dense ceiling.
        assert_eq!(sparse_expected_len(1024, 0.0), SPARSE_HEADER_BYTES + 4);
        assert_eq!(sparse_expected_len(1024, 1.0), SPARSE_HEADER_BYTES + 1024);
        assert_eq!(sparse_expected_len(0, 0.5), SPARSE_HEADER_BYTES + 4);
    }

    #[test]
    fn sparse_round_trips_and_never_exceeds_dense_plus_header() {
        let mut rng = crate::util::rng::Rng::new(17);
        for n in [1usize, 7, 8, 9, 64, 1024] {
            let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-2.0, 2.0)).collect();
            let mut enc = Vec::new();
            encode_activation(WireDtype::SparseI8, &x, &mut enc);
            // The hard ceiling: dense i8 body + sparse header.
            assert!(enc.len() <= SPARSE_HEADER_BYTES + n, "n={n}: {} bytes", enc.len());
            let st = sparse_stats(&enc).expect("encoder output must self-validate");
            assert_eq!(st.elems, n);
            let mut dec = vec![1.0f32; n];
            decode_activation_into(WireDtype::SparseI8, &enc, &mut dec).unwrap();
            // Every survivor matches the plain i8 quantizer; every
            // pruned element is exactly zero.
            let scale = f32::from_le_bytes(enc[1..5].try_into().unwrap());
            let inv = 1.0 / scale;
            for (a, b) in x.iter().zip(&dec) {
                let q = crate::runtime::linalg::quantize_one(*a, inv);
                assert!(*b == 0.0 || (*b - q as f32 * scale).abs() < 1e-12, "{a} -> {b}");
            }
            // The scale-defining max-|x| element always survives top-k.
            let mx = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            assert!(dec.iter().any(|v| (v.abs() - mx).abs() <= scale * 0.5 + 1e-7));
        }
    }

    #[test]
    fn sparse_keeps_at_most_the_topk_budget_on_spread_data() {
        // Uniform data has < 1/4 of its codes at any single magnitude,
        // so the histogram threshold lands the kept set within budget.
        let mut rng = crate::util::rng::Rng::new(23);
        let x: Vec<f32> = (0..1024).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let mut enc = Vec::new();
        encode_activation(WireDtype::SparseI8, &x, &mut enc);
        let st = sparse_stats(&enc).unwrap();
        assert!(st.nnz <= x.len() / SPARSE_KEEP_DIV, "kept {} of {}", st.nnz, x.len());
        // ... which makes the encoded frame >= 2x below dense i8.
        assert!(encoded_len(WireDtype::I8, x.len()) >= 2 * enc.len());
    }

    #[test]
    fn sparse_picks_the_cheaper_index_form_per_tensor() {
        // A handful of spikes in a long tensor: run-length beats bitmap.
        let mut spiky = vec![0.0f32; 512];
        for i in [3usize, 100, 101, 400, 511] {
            spiky[i] = 1.0;
        }
        let mut enc = Vec::new();
        encode_activation(WireDtype::SparseI8, &spiky, &mut enc);
        assert_eq!(enc[0], SPARSE_FORM_RLE);
        assert!(enc.len() < SPARSE_HEADER_BYTES + 512 / 8 + 5);
        let mut dec = vec![0.0f32; 512];
        decode_activation_into(WireDtype::SparseI8, &enc, &mut dec).unwrap();
        assert_eq!(dec, spiky);
        // A saturated tensor (every code at max) defeats pruning: the
        // dense fallback caps the damage at header + n.
        let flat = vec![1.0f32; 64];
        encode_activation(WireDtype::SparseI8, &flat, &mut enc);
        assert_eq!(enc[0], SPARSE_FORM_DENSE);
        assert_eq!(enc.len(), SPARSE_HEADER_BYTES + 64);
        decode_activation_into(WireDtype::SparseI8, &enc, &mut dec[..64]).unwrap();
        assert_eq!(&dec[..64], &flat[..]);
        // Spread data at the top-k density: bitmap wins.
        let mut rng = crate::util::rng::Rng::new(29);
        let spread: Vec<f32> = (0..1024).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        encode_activation(WireDtype::SparseI8, &spread, &mut enc);
        assert_eq!(enc[0], SPARSE_FORM_BITMAP);
    }

    #[test]
    fn sparse_all_zero_tensor_costs_header_plus_rle_count() {
        let x = [0.0f32; 1024];
        let mut enc = Vec::new();
        encode_activation(WireDtype::SparseI8, &x, &mut enc);
        assert_eq!(enc.len(), SPARSE_HEADER_BYTES + 4); // empty RLE list
        let mut dec = [1.0f32; 1024];
        decode_activation_into(WireDtype::SparseI8, &enc, &mut dec).unwrap();
        assert_eq!(dec, [0.0f32; 1024]);
    }

    #[test]
    fn sparse_decode_rejects_malformed_payloads() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32 / 9.0).sin()).collect();
        let mut enc = Vec::new();
        encode_activation(WireDtype::SparseI8, &x, &mut enc);
        let mut dec = vec![0.0f32; 64];
        // Truncations at every boundary: shorter than the header, a cut
        // index section, a cut code section — all errors, never panics.
        for cut in 0..enc.len() {
            assert!(
                decode_activation_into(WireDtype::SparseI8, &enc[..cut], &mut dec).is_err(),
                "truncation at {cut} must not decode"
            );
        }
        // Unknown form byte.
        let mut bad = enc.clone();
        bad[0] = 7;
        assert!(sparse_stats(&bad).is_none());
        // Element-count mismatch against the caller's tensor.
        assert!(decode_activation_into(WireDtype::SparseI8, &enc, &mut dec[..63]).is_err());
        // Bitmap form: stray bits past n are out-of-bounds indices.
        let mut bm = Vec::new();
        encode_activation(WireDtype::SparseI8, &x[..9], &mut bm); // n=9 -> 2 bitmap bytes
        if bm[0] == SPARSE_FORM_BITMAP {
            let mut stray = bm.clone();
            stray[SPARSE_HEADER_BYTES + 1] |= 0x80; // bit 15 of a 9-elem tensor
            assert!(sparse_stats(&stray).is_none());
        }
        // RLE form: a gap that walks the cursor past n.
        let spiky = {
            let mut v = vec![0.0f32; 64];
            v[60] = 1.0;
            v
        };
        let mut rle = Vec::new();
        encode_activation(WireDtype::SparseI8, &spiky, &mut rle);
        assert_eq!(rle[0], SPARSE_FORM_RLE);
        let mut overrun = rle.clone();
        overrun[SPARSE_HEADER_BYTES + 4] = 255; // gap 60 -> 255: cursor 256 > 64
        assert!(sparse_stats(&overrun).is_none());
        assert!(decode_activation_into(WireDtype::SparseI8, &overrun, &mut dec).is_err());
    }
}
