//! Distributed flight-recorder tracing: per-thread lock-free span rings
//! with cross-process span context, decomposing one inference into its
//! per-stage latency — client encode, link transit, reactor read, batch
//! linger, worker queue, per-layer kernel execution, response encode,
//! client decode.  This is the observability the paper's headline
//! end-to-end latency claim needs to be *explained* rather than merely
//! reported, and the measured counterpart the Explorer cost model is
//! calibrated against.
//!
//! Design (mirrors `server::spsc`'s ring discipline):
//!
//! * **Recording is wait-free and allocation-free.**  Each thread owns a
//!   fixed-capacity SPSC ring of [`Span`]s, lazily registered on its
//!   first recorded span (the one allocation, outside steady state).
//!   `push` is a Relaxed tail load + Acquire head load + slot write +
//!   Release tail store; a full ring drops the span and bumps a counter
//!   — tracing never blocks or backs up the serving path.
//! * **Runtime-gated and compile-out-able.**  Every record site first
//!   checks [`enabled`], a single relaxed atomic load.  Built without
//!   the `trace` cargo feature (in `default`), `enabled()` is a
//!   compile-time `false` and the dead-code eliminator removes the
//!   instrumentation entirely.  Sampling (`set_sampling`) traces one in
//!   N requests so an always-on deployment pays the ring write only on
//!   sampled frames.
//! * **Span context crosses the wire.**  A traced inference carries
//!   `[u64 trace_id][u32 parent_span]` ahead of its activation payload
//!   (protocol v3, `CAP_TRACE`), so client- and server-side spans share
//!   one trace and merge onto one timeline.  Timestamps are wall-clock
//!   microseconds since `UNIX_EPOCH` — on one host (the repro setup)
//!   both processes share the clock and the client-send → reactor-read
//!   gap *is* the link transit.
//! * **Draining is cold-path.**  [`drain`] walks the global recorder
//!   registry under a mutex (serializing consumers; each ring still has
//!   exactly one producer — its owning thread) and hands back an owned
//!   `Vec<Span>` for export: Chrome trace-event JSON
//!   ([`chrome_trace`], loadable in chrome://tracing / Perfetto) or the
//!   per-stage summary ([`summary_json`]) the `--metrics-addr` scrape
//!   endpoint serves.

use crate::util::json::Json;
use std::cell::{Cell, UnsafeCell};
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// One stage of the device–edge inference path.  The discriminant is
/// stable (spans survive snapshot/merge across processes built from the
/// same revision).
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Whole client-side request (root span; parent of everything).
    Request = 0,
    /// Client stages 1..pp + wire encode (`FrameScratch::frame_codec_into`).
    ClientEncode = 1,
    /// Frame write to the socket, including link-shaper pacing.
    ClientSend = 2,
    /// Blocking wait for the response frame.
    ClientWait = 3,
    /// Response verification / decode on the client.
    ClientDecode = 4,
    /// Reactor read readiness -> frame decoded -> request enqueued.
    ReactorRead = 5,
    /// Queue push -> dispatcher pop (the batch linger window).
    BatchLinger = 6,
    /// Dispatcher push -> worker pop (SPSC ring residence).
    WorkerQueue = 7,
    /// Whole server-side `EngineShard::infer_wire`.
    Infer = 8,
    /// One server-side layer/stage inside `Infer` (`arg` = stage index).
    Kernel = 9,
    /// Response wire-encode + write on the reactor thread.
    RespEncode = 10,
    /// Response served from the replay ring (no execution).
    Replay = 11,
    /// Dataflow TX FIFO frame send (`runtime::net`).
    NetTx = 12,
    /// Dataflow RX FIFO frame receive (`runtime::net`).
    NetRx = 13,
    /// Timer-wheel expiry batch (`runtime::reactor`; `arg` = fired count).
    TimerFire = 14,
    /// Dataflow actor firing (`runtime::engine`).
    ActorFire = 15,
    /// Activation wire encode (`runtime::wire`).
    WireEncode = 16,
    /// Activation wire decode (`runtime::wire`).
    WireDecode = 17,
}

pub const STAGE_COUNT: usize = 18;

const STAGE_NAMES: [&str; STAGE_COUNT] = [
    "request",
    "client_encode",
    "client_send",
    "client_wait",
    "client_decode",
    "reactor_read",
    "batch_linger",
    "worker_queue",
    "infer",
    "kernel",
    "resp_encode",
    "replay",
    "net_tx",
    "net_rx",
    "timer_fire",
    "actor_fire",
    "wire_encode",
    "wire_decode",
];

impl Stage {
    pub fn name(self) -> &'static str {
        STAGE_NAMES[self as usize]
    }
}

/// Trace id for process-local infrastructure spans that belong to no
/// particular request (timer fires, dataflow engine runs).  Exported on
/// the same timeline; never propagated over the wire.
pub const LOCAL: u64 = u64::MAX;

/// One completed span.  Fixed-size and `Copy` so ring slots never own
/// heap memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Which trace this span belongs to (0 never occurs in a ring;
    /// [`LOCAL`] marks infrastructure spans).
    pub trace_id: u64,
    /// Process-unique span id (>= 1).
    pub span_id: u32,
    /// Parent span id (0 = root / remote parent unknown).
    pub parent: u32,
    pub stage: Stage,
    /// Stage-specific argument (kernel stage index, timer fire count,
    /// payload bytes, ...).
    pub arg: u32,
    /// Wall-clock microseconds since `UNIX_EPOCH`.
    pub start_us: u64,
    pub dur_us: u64,
    /// Recorder (thread) id the span was recorded on.
    pub tid: u32,
}

// ------------------------------------------------------------- recorders

/// Spans retained per thread between drains.  A drain happens per
/// scrape / per run summary; at serving rates the ring wraps only if
/// nobody is listening, in which case dropping oldest-unread is the
/// correct flight-recorder behavior (`dropped()` reports it).
const RING_CAPACITY: usize = 4096;

struct Ring {
    id: u32,
    name: String,
    slots: Box<[UnsafeCell<MaybeUninit<Span>>]>,
    /// Consumer cursor (drain side, serialized by the registry lock).
    head: AtomicUsize,
    /// Producer cursor (owning thread only).
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// One producer (the owning thread), one consumer at a time (registry
// lock); the head/tail acquire/release pairs order the slot accesses.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn new(id: u32, name: String) -> Ring {
        let slots: Box<[UnsafeCell<MaybeUninit<Span>>]> =
            (0..RING_CAPACITY).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Ring {
            id,
            name,
            slots,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Producer side (owning thread only): wait-free, allocation-free.
    fn push(&self, span: Span) {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) >= RING_CAPACITY {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        unsafe {
            (*self.slots[tail % RING_CAPACITY].get()).write(span);
        }
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
    }

    /// Consumer side (under the registry lock).
    fn drain_into(&self, out: &mut Vec<Span>) {
        let tail = self.tail.load(Ordering::Acquire);
        let mut head = self.head.load(Ordering::Relaxed);
        while head != tail {
            let span = unsafe { (*self.slots[head % RING_CAPACITY].get()).assume_init_read() };
            out.push(span);
            head = head.wrapping_add(1);
        }
        self.head.store(head, Ordering::Release);
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Trace one in N requests (1 = every request).
static SAMPLE: AtomicU64 = AtomicU64::new(1);
static NEXT_SPAN: AtomicU32 = AtomicU32::new(1);
static NEXT_RECORDER: AtomicU32 = AtomicU32::new(1);
static NEXT_TRACE: AtomicU64 = AtomicU64::new(0);

fn registry() -> &'static Mutex<Vec<Arc<Ring>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static RECORDER: UnsafeCell<Option<Arc<Ring>>> = const { UnsafeCell::new(None) };
    /// Propagated span context for call sites too deep to thread
    /// parameters through (kernel loops, wire codecs).
    static CURRENT: Cell<(u64, u32)> = const { Cell::new((0, 0)) };
}

/// This thread's ring, registering it on first use (the one allocation;
/// warm it before any allocation-measured window via [`warm_recorder`]).
fn with_recorder<R>(f: impl FnOnce(&Ring) -> R) -> R {
    RECORDER.with(|slot| {
        // Safety: the slot is only ever touched from its owning thread,
        // and `f` cannot re-enter `with_recorder` (it only pushes).
        let opt = unsafe { &mut *slot.get() };
        if opt.is_none() {
            let id = NEXT_RECORDER.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current().name().unwrap_or("?").to_string();
            let ring = Arc::new(Ring::new(id, name));
            registry().lock().unwrap().push(ring.clone());
            *opt = Some(ring);
        }
        f(opt.as_ref().unwrap())
    })
}

// --------------------------------------------------------------- control

/// Is tracing live?  A compile-time `false` without the `trace` feature
/// (the whole subsystem then folds away); otherwise one relaxed load.
#[inline]
pub fn enabled() -> bool {
    cfg!(feature = "trace") && ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Trace one in `n` requests (0 and 1 both mean "every request").
pub fn set_sampling(n: u64) {
    SAMPLE.store(n.max(1), Ordering::Relaxed);
}

/// Should request number `seq` be traced?  (Client-side decision: the
/// server traces whatever arrives carrying a trace id.)
#[inline]
pub fn should_trace(seq: u64) -> bool {
    enabled() && seq % SAMPLE.load(Ordering::Relaxed) == 0
}

/// A fresh process-unique nonzero trace id.  High bits are seeded from
/// the wall clock once per process so ids from separately-started
/// client and server processes cannot collide.
pub fn next_trace_id() -> u64 {
    static SEED: OnceLock<u64> = OnceLock::new();
    let seed = *SEED.get_or_init(|| {
        let ns = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos();
        ((ns as u64) | 1) << 20
    });
    let id = seed.wrapping_add(NEXT_TRACE.fetch_add(1, Ordering::Relaxed));
    // 0 means "untraced" and LOCAL is reserved.
    if id == 0 || id == LOCAL {
        1
    } else {
        id
    }
}

fn next_span_id() -> u32 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// Wall-clock microseconds since `UNIX_EPOCH` (vDSO-cheap; shared by
/// client and server processes on one host, which is what lets their
/// spans merge onto one timeline).
#[inline]
pub fn now_us() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_micros() as u64
}

/// Total spans dropped to full rings since process start.
pub fn dropped() -> u64 {
    registry().lock().unwrap().iter().map(|r| r.dropped.load(Ordering::Relaxed)).sum()
}

/// Register this thread's recorder ahead of time, so the lazy-init
/// allocation happens outside any allocation-measured window.
pub fn warm_recorder() {
    if cfg!(feature = "trace") {
        with_recorder(|_| ());
    }
}

// ----------------------------------------------------- span propagation

/// Set the span context deep call sites (kernels, wire codecs) record
/// under.  `(0, 0)` clears it.
pub fn set_current(trace_id: u64, parent: u32) {
    CURRENT.with(|c| c.set((trace_id, parent)));
}

/// The propagated `(trace_id, parent_span)` for this thread, `(0, 0)`
/// when none.
pub fn current() -> (u64, u32) {
    CURRENT.with(|c| c.get())
}

pub fn clear_current() {
    set_current(0, 0);
}

// ------------------------------------------------------------ recording

/// Record a completed span with explicit timestamps (the cross-thread
/// reconstruction path: batch-linger and worker-queue windows measured
/// from timestamps carried in `PendingRequest`).  Returns the span id
/// (0 if tracing was off or `trace_id` is 0).
pub fn record(
    trace_id: u64,
    parent: u32,
    stage: Stage,
    arg: u32,
    start_us: u64,
    end_us: u64,
) -> u32 {
    if !enabled() || trace_id == 0 {
        return 0;
    }
    let span_id = next_span_id();
    with_recorder(|ring| {
        ring.push(Span {
            trace_id,
            span_id,
            parent,
            stage,
            arg,
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            tid: ring.id,
        });
    });
    span_id
}

/// RAII span: times from construction to drop.  Constructing with
/// `trace_id == 0` (or tracing disabled) is a no-op guard.
pub struct SpanGuard {
    trace_id: u64,
    parent: u32,
    stage: Stage,
    arg: u32,
    start_us: u64,
    id: u32,
}

/// Open a span under `(trace_id, parent)`.
pub fn span(trace_id: u64, parent: u32, stage: Stage, arg: u32) -> SpanGuard {
    if !enabled() || trace_id == 0 {
        return SpanGuard { trace_id: 0, parent: 0, stage, arg: 0, start_us: 0, id: 0 };
    }
    SpanGuard { trace_id, parent, stage, arg, start_us: now_us(), id: next_span_id() }
}

/// Open a span under this thread's propagated context ([`set_current`]);
/// a no-op guard when no context is set.
pub fn span_current(stage: Stage, arg: u32) -> SpanGuard {
    let (trace_id, parent) = current();
    span(trace_id, parent, stage, arg)
}

impl SpanGuard {
    /// The span id (to parent children under); 0 on a no-op guard.
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Is this guard actually recording?
    pub fn live(&self) -> bool {
        self.trace_id != 0
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.trace_id == 0 {
            return;
        }
        let end = now_us();
        let span = Span {
            trace_id: self.trace_id,
            span_id: self.id,
            parent: self.parent,
            stage: self.stage,
            arg: self.arg,
            start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            tid: 0,
        };
        with_recorder(|ring| {
            ring.push(Span { tid: ring.id, ..span });
        });
    }
}

// --------------------------------------------------------------- export

/// Drain every recorder's retained spans (cold path; allocates).  Spans
/// come back grouped by recorder, each group in record order.
pub fn drain() -> Vec<Span> {
    let mut out = Vec::new();
    for ring in registry().lock().unwrap().iter() {
        ring.drain_into(&mut out);
    }
    out
}

/// Recorder-id -> thread-name rows for export labeling.
pub fn recorder_names() -> Vec<(u32, String)> {
    registry().lock().unwrap().iter().map(|r| (r.id, r.name.clone())).collect()
}

/// Trace ids are full-range u64 (clock-seeded high bits; `LOCAL` is
/// `u64::MAX`), which a JSON number cannot carry exactly — the shared
/// `Json` type stores f64, whose 53-bit mantissa would collapse ids
/// that differ only in their low (counter) bits.  They travel as hex
/// strings instead.
fn trace_id_json(id: u64) -> Json {
    Json::from(format!("{id:x}"))
}

fn trace_id_from_json(v: &Json) -> anyhow::Result<u64> {
    Ok(u64::from_str_radix(v.str()?, 16)?)
}

fn span_json(s: &Span) -> Json {
    Json::from_pairs(vec![
        ("trace_id", trace_id_json(s.trace_id)),
        ("span_id", Json::from(u64::from(s.span_id))),
        ("parent", Json::from(u64::from(s.parent))),
        ("stage", Json::from(s.stage.name())),
        ("arg", Json::from(u64::from(s.arg))),
        ("start_us", Json::from(s.start_us)),
        ("dur_us", Json::from(s.dur_us)),
        ("tid", Json::from(u64::from(s.tid))),
    ])
}

/// Spans as plain JSON rows (the scrape endpoint's `trace.spans` field;
/// parse back with [`span_from_json`]).
pub fn spans_json(spans: &[Span]) -> Json {
    Json::Arr(spans.iter().map(span_json).collect())
}

fn stage_from_name(name: &str) -> Option<Stage> {
    STAGE_NAMES.iter().position(|&n| n == name).map(|i| match i {
        0 => Stage::Request,
        1 => Stage::ClientEncode,
        2 => Stage::ClientSend,
        3 => Stage::ClientWait,
        4 => Stage::ClientDecode,
        5 => Stage::ReactorRead,
        6 => Stage::BatchLinger,
        7 => Stage::WorkerQueue,
        8 => Stage::Infer,
        9 => Stage::Kernel,
        10 => Stage::RespEncode,
        11 => Stage::Replay,
        12 => Stage::NetTx,
        13 => Stage::NetRx,
        14 => Stage::TimerFire,
        15 => Stage::ActorFire,
        16 => Stage::WireEncode,
        _ => Stage::WireDecode,
    })
}

/// Parse one span row produced by [`spans_json`] (how `loadgen` ingests
/// the server's spans from the scrape snapshot to merge traces).
pub fn span_from_json(v: &Json) -> anyhow::Result<Span> {
    let stage_name = v.get("stage")?.str()?.to_string();
    let stage = stage_from_name(&stage_name)
        .ok_or_else(|| anyhow::anyhow!("unknown trace stage {stage_name:?}"))?;
    Ok(Span {
        trace_id: trace_id_from_json(v.get("trace_id")?)?,
        span_id: v.get("span_id")?.int()? as u32,
        parent: v.get("parent")?.int()? as u32,
        stage,
        arg: v.get("arg")?.int()? as u32,
        start_us: v.get("start_us")?.int()? as u64,
        dur_us: v.get("dur_us")?.int()? as u64,
        tid: v.get("tid")?.int()? as u32,
    })
}

/// Merge span groups into one Chrome trace-event JSON object
/// (chrome://tracing / Perfetto "Open trace file").  Each `(name,
/// spans)` group becomes one process on the shared wall-clock
/// timeline; recorder ids become threads.
pub fn chrome_trace(groups: &[(&str, &[Span])]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (pid, (name, spans)) in groups.iter().enumerate() {
        let pid = pid as u64 + 1;
        events.push(Json::from_pairs(vec![
            ("name", Json::from("process_name")),
            ("ph", Json::from("M")),
            ("pid", Json::from(pid)),
            ("tid", Json::from(0u64)),
            ("args", Json::from_pairs(vec![("name", Json::from(*name))])),
        ]));
        let mut tids_seen: Vec<u32> = Vec::new();
        for s in spans.iter() {
            if !tids_seen.contains(&s.tid) {
                tids_seen.push(s.tid);
            }
            events.push(Json::from_pairs(vec![
                ("name", Json::from(s.stage.name())),
                ("cat", Json::from("edge-prune")),
                ("ph", Json::from("X")),
                ("ts", Json::from(s.start_us)),
                ("dur", Json::from(s.dur_us)),
                ("pid", Json::from(pid)),
                ("tid", Json::from(u64::from(s.tid))),
                (
                    "args",
                    Json::from_pairs(vec![
                        ("trace_id", trace_id_json(s.trace_id)),
                        ("span_id", Json::from(u64::from(s.span_id))),
                        ("parent", Json::from(u64::from(s.parent))),
                        ("arg", Json::from(u64::from(s.arg))),
                    ]),
                ),
            ]));
        }
        for (rid, rname) in recorder_names() {
            if tids_seen.contains(&rid) {
                events.push(Json::from_pairs(vec![
                    ("name", Json::from("thread_name")),
                    ("ph", Json::from("M")),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(u64::from(rid))),
                    ("args", Json::from_pairs(vec![("name", Json::from(rname.as_str()))])),
                ]));
            }
        }
    }
    Json::from_pairs(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

/// Per-stage aggregate over a span set: count / total / mean / min /
/// max microseconds, one row per stage that occurred.  This is the
/// "per-stage latency decomposition" table the scrape endpoint and the
/// calibration report are built on.
pub fn summary_json(spans: &[Span]) -> Json {
    let mut count = [0u64; STAGE_COUNT];
    let mut total = [0u64; STAGE_COUNT];
    let mut min = [u64::MAX; STAGE_COUNT];
    let mut max = [0u64; STAGE_COUNT];
    for s in spans {
        let i = s.stage as usize;
        count[i] += 1;
        total[i] += s.dur_us;
        min[i] = min[i].min(s.dur_us);
        max[i] = max[i].max(s.dur_us);
    }
    let rows: Vec<Json> = (0..STAGE_COUNT)
        .filter(|&i| count[i] > 0)
        .map(|i| {
            Json::from_pairs(vec![
                ("stage", Json::from(STAGE_NAMES[i])),
                ("count", Json::from(count[i])),
                ("total_us", Json::from(total[i])),
                ("mean_us", Json::from(total[i] as f64 / count[i] as f64)),
                ("min_us", Json::from(min[i])),
                ("max_us", Json::from(max[i])),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("spans", Json::from(spans.len())),
        ("dropped", Json::from(dropped())),
        ("stages", Json::Arr(rows)),
    ])
}

/// Mean duration (ms) of `stage` over a span set (`None` if absent) —
/// the calibration report's accessor.
pub fn mean_stage_ms(spans: &[Span], stage: Stage) -> Option<f64> {
    let (mut n, mut total) = (0u64, 0u64);
    for s in spans.iter().filter(|s| s.stage == stage) {
        n += 1;
        total += s.dur_us;
    }
    (n > 0).then(|| total as f64 / n as f64 / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests in this module toggle the global enable flag; serialize
    /// them so a parallel test harness cannot interleave.
    fn exclusive() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disabled_tracing_records_nothing() {
        let _x = exclusive();
        set_enabled(false);
        let _ = drain();
        let g = span(42, 0, Stage::Infer, 0);
        assert!(!g.live());
        drop(g);
        record(42, 0, Stage::Kernel, 1, 10, 20);
        assert!(drain().is_empty());
    }

    #[test]
    fn spans_nest_and_order_under_one_trace() {
        let _x = exclusive();
        set_enabled(true);
        set_sampling(1);
        let _ = drain();
        let trace = next_trace_id();
        let root = span(trace, 0, Stage::Request, 0);
        let root_id = root.id();
        assert!(root.live() && root_id > 0);
        let child = span(trace, root_id, Stage::ClientEncode, 0);
        let child_id = child.id();
        std::thread::sleep(std::time::Duration::from_millis(2));
        drop(child);
        drop(root);
        set_enabled(false);

        let spans = drain();
        assert_eq!(spans.len(), 2);
        // Guards drop inside-out: the child is recorded first.
        let (c, r) = (&spans[0], &spans[1]);
        assert_eq!(c.stage, Stage::ClientEncode);
        assert_eq!(r.stage, Stage::Request);
        assert_eq!(c.trace_id, trace);
        assert_eq!(r.trace_id, trace);
        assert_eq!(c.parent, root_id);
        assert_eq!(c.span_id, child_id);
        // Nesting invariant: the child interval sits inside the parent.
        assert!(c.start_us >= r.start_us);
        assert!(c.start_us + c.dur_us <= r.start_us + r.dur_us);
        assert!(r.dur_us >= 2_000, "parent covers the 2 ms sleep");
    }

    #[test]
    fn explicit_record_and_current_context() {
        let _x = exclusive();
        set_enabled(true);
        let _ = drain();
        let trace = next_trace_id();
        set_current(trace, 7);
        let g = span_current(Stage::Kernel, 3);
        assert!(g.live());
        drop(g);
        clear_current();
        assert!(!span_current(Stage::Kernel, 0).live(), "cleared context records nothing");
        let id = record(trace, 7, Stage::BatchLinger, 0, 100, 250);
        assert!(id > 0);
        set_enabled(false);
        let spans = drain();
        assert_eq!(spans.len(), 2);
        let linger = spans.iter().find(|s| s.stage == Stage::BatchLinger).unwrap();
        assert_eq!((linger.start_us, linger.dur_us, linger.parent), (100, 150, 7));
        assert_eq!(spans.iter().find(|s| s.stage == Stage::Kernel).unwrap().arg, 3);
    }

    #[test]
    fn full_ring_drops_instead_of_blocking() {
        let _x = exclusive();
        set_enabled(true);
        let _ = drain();
        let before = dropped();
        let trace = next_trace_id();
        for i in 0..(RING_CAPACITY as u32 + 100) {
            record(trace, 0, Stage::Kernel, i, 0, 1);
        }
        set_enabled(false);
        assert!(dropped() >= before + 100, "overflow increments the dropped counter");
        let spans = drain();
        assert_eq!(spans.iter().filter(|s| s.trace_id == trace).count(), RING_CAPACITY);
    }

    #[test]
    fn sampling_selects_one_in_n() {
        let _x = exclusive();
        set_enabled(true);
        set_sampling(8);
        let picked = (0..64u64).filter(|&s| should_trace(s)).count();
        assert_eq!(picked, 8);
        set_sampling(1);
        assert!(should_trace(17));
        set_enabled(false);
        assert!(!should_trace(0), "sampling never overrides the enable gate");
    }

    #[test]
    fn chrome_export_and_json_round_trip() {
        let _x = exclusive();
        set_enabled(true);
        let _ = drain();
        let trace = next_trace_id();
        record(trace, 0, Stage::ClientSend, 0, 1000, 1500);
        record(trace, 0, Stage::ReactorRead, 0, 1600, 1700);
        set_enabled(false);
        let spans = drain();

        // Plain-JSON rows parse back losslessly (the scrape transport).
        let rows = spans_json(&spans);
        let parsed = Json::parse(&rows.to_string()).unwrap();
        let back: Vec<Span> =
            parsed.arr().unwrap().iter().map(|v| span_from_json(v).unwrap()).collect();
        assert_eq!(back, spans);

        // Chrome export: one process per group, complete events, both
        // process metadata and span events present, valid JSON.
        let client: Vec<Span> =
            spans.iter().filter(|s| s.stage == Stage::ClientSend).copied().collect();
        let server: Vec<Span> =
            spans.iter().filter(|s| s.stage == Stage::ReactorRead).copied().collect();
        let chrome = chrome_trace(&[("client", &client), ("server", &server)]);
        let parsed = Json::parse(&chrome.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().arr().unwrap();
        assert!(events.iter().any(|e| e.get("ph").unwrap().str().unwrap() == "M"));
        let xs: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").unwrap().str().unwrap() == "X").collect();
        assert_eq!(xs.len(), 2);
        let pids: std::collections::BTreeSet<i64> =
            xs.iter().map(|e| e.get("pid").unwrap().int().unwrap()).collect();
        assert_eq!(pids.len(), 2, "client and server land on distinct processes");

        let summary = summary_json(&spans);
        let stages = summary.get("stages").unwrap().arr().unwrap();
        assert_eq!(stages.len(), 2);
        let send = stages
            .iter()
            .find(|r| r.get("stage").unwrap().str().unwrap() == "client_send")
            .unwrap();
        assert_eq!(send.get("mean_us").unwrap().num().unwrap(), 500.0);
        assert_eq!(mean_stage_ms(&spans, Stage::ClientSend), Some(0.5));
        assert_eq!(mean_stage_ms(&spans, Stage::Kernel), None);
    }
}
