//! Bounded FIFO buffers (paper §III.D): "actor data exchange over FIFOs is
//! synchronized by mutex primitives".  Blocking push/pop with Condvar
//! wake-ups, capacity enforcement, end-of-stream close semantics, and an
//! occupancy high-water mark (checked against the analyzer's bounds in
//! tests).

use crate::dataflow::Token;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

#[derive(Debug)]
struct State {
    queue: VecDeque<Token>,
    closed: bool,
    max_occupancy: usize,
    // Perf: waiter counts let push/pop skip the condvar notify syscall on
    // the uncontended fast path (see EXPERIMENTS.md SPerf).
    waiting_consumers: usize,
    waiting_producers: usize,
}

#[derive(Debug)]
pub struct Fifo {
    capacity: usize,
    state: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl Fifo {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "FIFO capacity must be positive");
        Fifo {
            capacity,
            state: Mutex::new(State {
                queue: VecDeque::with_capacity(capacity),
                closed: false,
                max_occupancy: 0,
                waiting_consumers: 0,
                waiting_producers: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Pre-load initial tokens (dataflow "delays" on feedback edges).
    pub fn preload(&self, tokens: Vec<Token>) {
        let mut s = self.state.lock().unwrap();
        assert!(s.queue.len() + tokens.len() <= self.capacity);
        s.queue.extend(tokens);
        s.max_occupancy = s.max_occupancy.max(s.queue.len());
        drop(s);
        self.not_empty.notify_all();
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocking push; returns false if the FIFO was closed by the consumer
    /// (downstream cancelled — producer should wind down).
    pub fn push(&self, token: Token) -> bool {
        let mut s = self.state.lock().unwrap();
        while s.queue.len() >= self.capacity && !s.closed {
            s.waiting_producers += 1;
            s = self.not_full.wait(s).unwrap();
            s.waiting_producers -= 1;
        }
        if s.closed {
            return false;
        }
        s.queue.push_back(token);
        let occ = s.queue.len();
        s.max_occupancy = s.max_occupancy.max(occ);
        let wake = s.waiting_consumers > 0;
        drop(s);
        if wake {
            self.not_empty.notify_one();
        }
        true
    }

    /// Blocking pop of exactly `n` tokens (the consumer's atr); returns
    /// None once the FIFO is closed and fewer than `n` remain.
    pub fn pop_n(&self, n: usize) -> Option<Vec<Token>> {
        let mut s = self.state.lock().unwrap();
        while s.queue.len() < n && !s.closed {
            s.waiting_consumers += 1;
            s = self.not_empty.wait(s).unwrap();
            s.waiting_consumers -= 1;
        }
        if s.queue.len() < n {
            return None; // closed with insufficient tokens
        }
        let out: Vec<Token> = s.queue.drain(..n).collect();
        let wake = s.waiting_producers > 0;
        drop(s);
        if wake {
            self.not_full.notify_all();
        }
        Some(out)
    }

    /// Non-blocking pop of up to n tokens (used by drain paths / tests).
    pub fn try_pop_n(&self, n: usize) -> Option<Vec<Token>> {
        let mut s = self.state.lock().unwrap();
        if s.queue.len() < n {
            return None;
        }
        let out: Vec<Token> = s.queue.drain(..n).collect();
        let wake = s.waiting_producers > 0;
        drop(s);
        if wake {
            self.not_full.notify_all();
        }
        Some(out)
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// End-of-stream: wakes all blocked producers and consumers.  Tokens
    /// already queued remain poppable (pop_n drains the tail).
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        drop(s);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    pub fn max_occupancy(&self) -> usize {
        self.state.lock().unwrap().max_occupancy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn tok(v: u8) -> Token {
        Token::new(vec![v], v as u64)
    }

    #[test]
    fn fifo_order_preserved() {
        let f = Fifo::new(4);
        for i in 0..4 {
            assert!(f.push(tok(i)));
        }
        let got = f.pop_n(4).unwrap();
        assert_eq!(got.iter().map(|t| t.data[0]).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn push_blocks_at_capacity_until_pop() {
        let f = Arc::new(Fifo::new(2));
        f.push(tok(1));
        f.push(tok(2));
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.push(tok(3)));
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(f.len(), 2); // producer is blocked
        f.pop_n(1).unwrap();
        assert!(h.join().unwrap());
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn pop_blocks_until_push() {
        let f = Arc::new(Fifo::new(2));
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.pop_n(1));
        std::thread::sleep(Duration::from_millis(30));
        f.push(tok(9));
        let got = h.join().unwrap().unwrap();
        assert_eq!(got[0].data[0], 9);
    }

    #[test]
    fn close_unblocks_consumer_with_none() {
        let f = Arc::new(Fifo::new(2));
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.pop_n(1));
        std::thread::sleep(Duration::from_millis(30));
        f.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_unblocks_producer_with_false() {
        let f = Arc::new(Fifo::new(1));
        f.push(tok(1));
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.push(tok(2)));
        std::thread::sleep(Duration::from_millis(30));
        f.close();
        assert!(!h.join().unwrap());
    }

    #[test]
    fn tail_drain_after_close() {
        let f = Fifo::new(4);
        f.push(tok(1));
        f.push(tok(2));
        f.close();
        assert_eq!(f.pop_n(2).unwrap().len(), 2);
        assert!(f.pop_n(1).is_none());
    }

    #[test]
    fn multirate_pop() {
        let f = Fifo::new(8);
        for i in 0..6 {
            f.push(tok(i));
        }
        assert_eq!(f.pop_n(3).unwrap().len(), 3);
        assert_eq!(f.try_pop_n(3).unwrap().len(), 3);
        assert!(f.try_pop_n(1).is_none());
    }

    #[test]
    fn max_occupancy_tracks_high_water() {
        let f = Fifo::new(8);
        for i in 0..5 {
            f.push(tok(i));
        }
        f.pop_n(4).unwrap();
        f.push(tok(9));
        assert_eq!(f.max_occupancy(), 5);
    }

    #[test]
    fn preload_initial_tokens() {
        let f = Fifo::new(2);
        f.preload(vec![tok(7)]);
        assert_eq!(f.len(), 1);
        assert_eq!(f.pop_n(1).unwrap()[0].data[0], 7);
    }

    #[test]
    fn slow_consumer_backpressures_producer() {
        // Capacity-2 FIFO, consumer pops one token every 4 ms: the
        // producer cannot run ahead, so pushing 20 tokens takes at least
        // (20 - 2) * 4 ms and occupancy never exceeds capacity.
        let f = Arc::new(Fifo::new(2));
        let f2 = f.clone();
        let producer = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            for i in 0..20 {
                assert!(f2.push(tok(i)));
            }
            t0.elapsed()
        });
        let consumer = std::thread::spawn({
            let f = f.clone();
            move || {
                let mut n = 0;
                while n < 20 {
                    std::thread::sleep(Duration::from_millis(4));
                    if f.pop_n(1).is_some() {
                        n += 1;
                    }
                }
            }
        });
        let produce_time = producer.join().unwrap();
        consumer.join().unwrap();
        assert!(
            produce_time >= Duration::from_millis(60),
            "producer outran the slow consumer: {produce_time:?}"
        );
        assert!(f.max_occupancy() <= 2, "occupancy {} > capacity", f.max_occupancy());
    }

    #[test]
    fn concurrent_producers_consumers_conserve_tokens() {
        let f = Arc::new(Fifo::new(4));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let f = f.clone();
                std::thread::spawn(move || {
                    for i in 0..50 {
                        f.push(Token::new(vec![p as u8], i));
                    }
                })
            })
            .collect();
        let consumed = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let f = f.clone();
                let c = consumed.clone();
                std::thread::spawn(move || {
                    while f.pop_n(1).is_some() {
                        c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        // Give consumers time to drain, then close.
        while !f.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        }
        f.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(consumed.load(std::sync::atomic::Ordering::Relaxed), 200);
        assert!(f.max_occupancy() <= 4);
    }
}
