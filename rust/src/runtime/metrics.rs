//! Execution metrics: per-actor firing counts and busy time, plus
//! pipeline-level frame accounting.  This is what the Explorer's profiling
//! mode and the figure benches read out.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct ActorStats {
    pub firings: u64,
    pub busy: Duration,
    /// Time spent blocked pushing to output FIFOs (backpressure).
    pub blocked_out: Duration,
    /// Time spent waiting for input tokens.
    pub blocked_in: Duration,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<BTreeMap<String, ActorStats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &self,
        actor: &str,
        busy: Duration,
        blocked_in: Duration,
        blocked_out: Duration,
    ) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(actor.to_string()).or_default();
        s.firings += 1;
        s.busy += busy;
        s.blocked_in += blocked_in;
        s.blocked_out += blocked_out;
    }

    pub fn snapshot(&self) -> BTreeMap<String, ActorStats> {
        self.inner.lock().unwrap().clone()
    }
}

/// Result of one engine run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub device: String,
    pub wall: Duration,
    /// Frames fully consumed by sink actors (max over sinks).
    pub frames: u64,
    pub actors: BTreeMap<String, ActorStats>,
}

impl RunReport {
    pub fn ms_per_frame(&self) -> f64 {
        if self.frames == 0 {
            return f64::NAN;
        }
        self.wall.as_secs_f64() * 1e3 / self.frames as f64
    }

    /// Sum of per-actor busy time divided by frames: the "device compute
    /// time per frame" figure, independent of pipeline overlap.
    pub fn busy_ms_per_frame(&self) -> f64 {
        if self.frames == 0 {
            return f64::NAN;
        }
        let busy: Duration = self.actors.values().map(|s| s.busy).sum();
        busy.as_secs_f64() * 1e3 / self.frames as f64
    }

    pub fn to_json(&self) -> Json {
        let actors: Vec<Json> = self
            .actors
            .iter()
            .map(|(name, s)| {
                Json::from_pairs(vec![
                    ("actor", Json::from(name.as_str())),
                    ("firings", Json::from(s.firings)),
                    ("busy_ms", Json::from(s.busy.as_secs_f64() * 1e3)),
                    ("blocked_in_ms", Json::from(s.blocked_in.as_secs_f64() * 1e3)),
                    ("blocked_out_ms", Json::from(s.blocked_out.as_secs_f64() * 1e3)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("device", Json::from(self.device.as_str())),
            ("wall_ms", Json::from(self.wall.as_secs_f64() * 1e3)),
            ("frames", Json::from(self.frames)),
            ("ms_per_frame", Json::from(self.ms_per_frame())),
            ("actors", Json::Arr(actors)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates() {
        let m = Metrics::new();
        m.record("a", Duration::from_millis(2), Duration::ZERO, Duration::ZERO);
        m.record("a", Duration::from_millis(3), Duration::from_millis(1), Duration::ZERO);
        let s = m.snapshot();
        assert_eq!(s["a"].firings, 2);
        assert_eq!(s["a"].busy, Duration::from_millis(5));
        assert_eq!(s["a"].blocked_in, Duration::from_millis(1));
    }

    #[test]
    fn report_rates() {
        let mut actors = BTreeMap::new();
        actors.insert(
            "x".to_string(),
            ActorStats { firings: 10, busy: Duration::from_millis(50), ..Default::default() },
        );
        let r = RunReport {
            device: "n2".into(),
            wall: Duration::from_millis(200),
            frames: 10,
            actors,
        };
        assert!((r.ms_per_frame() - 20.0).abs() < 1e-9);
        assert!((r.busy_ms_per_frame() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn report_json_shape() {
        let r = RunReport {
            device: "d".into(),
            wall: Duration::from_millis(10),
            frames: 1,
            actors: BTreeMap::new(),
        };
        let j = r.to_json();
        assert_eq!(j.get("device").unwrap().str().unwrap(), "d");
        assert_eq!(j.get("frames").unwrap().int().unwrap(), 1);
    }

    #[test]
    fn zero_frames_is_nan() {
        let r = RunReport {
            device: "d".into(),
            wall: Duration::from_millis(10),
            frames: 0,
            actors: BTreeMap::new(),
        };
        assert!(r.ms_per_frame().is_nan());
    }
}
