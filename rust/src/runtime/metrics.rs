//! Execution metrics: per-actor firing counts and busy time, plus
//! pipeline-level frame accounting.  This is what the Explorer's profiling
//! mode and the figure benches read out.
//!
//! Also home to the lock-free `LatencyHistogram` the serving layer
//! (`crate::server`) uses for per-plan p50/p95/p99 request latency.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct ActorStats {
    pub firings: u64,
    pub busy: Duration,
    /// Time spent blocked pushing to output FIFOs (backpressure).
    pub blocked_out: Duration,
    /// Time spent waiting for input tokens.
    pub blocked_in: Duration,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<BTreeMap<String, ActorStats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &self,
        actor: &str,
        busy: Duration,
        blocked_in: Duration,
        blocked_out: Duration,
    ) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(actor.to_string()).or_default();
        s.firings += 1;
        s.busy += busy;
        s.blocked_in += blocked_in;
        s.blocked_out += blocked_out;
    }

    pub fn snapshot(&self) -> BTreeMap<String, ActorStats> {
        self.inner.lock().unwrap().clone()
    }
}

/// Result of one engine run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub device: String,
    pub wall: Duration,
    /// Frames fully consumed by sink actors (max over sinks).
    pub frames: u64,
    pub actors: BTreeMap<String, ActorStats>,
}

impl RunReport {
    pub fn ms_per_frame(&self) -> f64 {
        if self.frames == 0 {
            return f64::NAN;
        }
        self.wall.as_secs_f64() * 1e3 / self.frames as f64
    }

    /// Sum of per-actor busy time divided by frames: the "device compute
    /// time per frame" figure, independent of pipeline overlap.
    pub fn busy_ms_per_frame(&self) -> f64 {
        if self.frames == 0 {
            return f64::NAN;
        }
        let busy: Duration = self.actors.values().map(|s| s.busy).sum();
        busy.as_secs_f64() * 1e3 / self.frames as f64
    }

    pub fn to_json(&self) -> Json {
        let actors: Vec<Json> = self
            .actors
            .iter()
            .map(|(name, s)| {
                Json::from_pairs(vec![
                    ("actor", Json::from(name.as_str())),
                    ("firings", Json::from(s.firings)),
                    ("busy_ms", Json::from(s.busy.as_secs_f64() * 1e3)),
                    ("blocked_in_ms", Json::from(s.blocked_in.as_secs_f64() * 1e3)),
                    ("blocked_out_ms", Json::from(s.blocked_out.as_secs_f64() * 1e3)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("device", Json::from(self.device.as_str())),
            ("wall_ms", Json::from(self.wall.as_secs_f64() * 1e3)),
            ("frames", Json::from(self.frames)),
            ("ms_per_frame", Json::from(self.ms_per_frame())),
            ("actors", Json::Arr(actors)),
        ])
    }
}

/// Link-byte accounting for the compact activation wire format: actual
/// bytes moved in each direction plus the f32-equivalent byte count
/// (what the same tensors would have cost in the legacy raw-f32
/// format).  The ratio of the two is the wire-compression-ratio gauge
/// the loadgen and serve summaries report — ~1.0 on an f32 session,
/// approaching 4.0 on an int8 one.  Plain relaxed atomics: wait-free
/// from any number of connections.
#[derive(Debug, Default)]
pub struct WireCounters {
    pub bytes_tx: AtomicU64,
    pub bytes_rx: AtomicU64,
    pub f32_equiv_tx: AtomicU64,
    pub f32_equiv_rx: AtomicU64,
    /// Elements carried by sparse-coded payloads (the achieved-sparsity
    /// gauge's denominator).
    pub sparse_elems: AtomicU64,
    /// Coefficients those payloads actually shipped (its numerator).
    pub sparse_nnz: AtomicU64,
    /// Bytes saved vs the dense-i8 encoding of the same tensors.
    pub sparse_saved: AtomicU64,
}

impl WireCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn note_tx(&self, actual: u64, f32_equiv: u64) {
        self.bytes_tx.fetch_add(actual, Ordering::Relaxed);
        self.f32_equiv_tx.fetch_add(f32_equiv, Ordering::Relaxed);
    }

    pub fn note_rx(&self, actual: u64, f32_equiv: u64) {
        self.bytes_rx.fetch_add(actual, Ordering::Relaxed);
        self.f32_equiv_rx.fetch_add(f32_equiv, Ordering::Relaxed);
    }

    /// One sparse-coded payload went by: what its header declared
    /// (element count, shipped coefficients) and what it actually cost,
    /// vs the `4 + elems` bytes dense i8 would have taken.
    pub fn note_sparse(&self, st: crate::runtime::wire::SparseStats, encoded_bytes: usize) {
        self.sparse_elems.fetch_add(st.elems as u64, Ordering::Relaxed);
        self.sparse_nnz.fetch_add(st.nnz as u64, Ordering::Relaxed);
        let dense = 4 + st.elems as u64;
        self.sparse_saved.fetch_add(dense.saturating_sub(encoded_bytes as u64), Ordering::Relaxed);
    }

    /// Fraction of elements pruned off sparse payloads: `1 - nnz/elems`
    /// (0.0 while no sparse traffic has moved, so the idle gauge reads
    /// neutral).
    pub fn achieved_sparsity(&self) -> f64 {
        let elems = self.sparse_elems.load(Ordering::Relaxed);
        if elems == 0 {
            return 0.0;
        }
        1.0 - self.sparse_nnz.load(Ordering::Relaxed) as f64 / elems as f64
    }

    /// f32-equivalent bytes / actual bytes over both directions
    /// (1.0 when nothing has moved, so an idle gauge reads neutral).
    pub fn compression_ratio(&self) -> f64 {
        let actual = self.bytes_tx.load(Ordering::Relaxed) + self.bytes_rx.load(Ordering::Relaxed);
        if actual == 0 {
            return 1.0;
        }
        let equiv =
            self.f32_equiv_tx.load(Ordering::Relaxed) + self.f32_equiv_rx.load(Ordering::Relaxed);
        equiv as f64 / actual as f64
    }

    /// Fold another counter set into this one.  Pure addition in every
    /// field, so merging N per-shard counters at scrape time is lossless:
    /// the result is bitwise what a single shared counter would hold.
    pub fn merge_from(&self, other: &WireCounters) {
        self.bytes_tx.fetch_add(other.bytes_tx.load(Ordering::Relaxed), Ordering::Relaxed);
        self.bytes_rx.fetch_add(other.bytes_rx.load(Ordering::Relaxed), Ordering::Relaxed);
        self.f32_equiv_tx.fetch_add(other.f32_equiv_tx.load(Ordering::Relaxed), Ordering::Relaxed);
        self.f32_equiv_rx.fetch_add(other.f32_equiv_rx.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sparse_elems.fetch_add(other.sparse_elems.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sparse_nnz.fetch_add(other.sparse_nnz.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sparse_saved.fetch_add(other.sparse_saved.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("bytes_tx", Json::from(self.bytes_tx.load(Ordering::Relaxed))),
            ("bytes_rx", Json::from(self.bytes_rx.load(Ordering::Relaxed))),
            ("f32_equiv_tx", Json::from(self.f32_equiv_tx.load(Ordering::Relaxed))),
            ("f32_equiv_rx", Json::from(self.f32_equiv_rx.load(Ordering::Relaxed))),
            ("compression_ratio", Json::from(self.compression_ratio())),
            ("achieved_sparsity", Json::from(self.achieved_sparsity())),
            ("sparse_bytes_saved", Json::from(self.sparse_saved.load(Ordering::Relaxed))),
        ])
    }
}

/// Lock-free log-linear latency histogram (HDR-style): exact buckets
/// below 8 µs, then 8 linear sub-buckets per power of two — quantile
/// error is bounded at ~6% of the value, with constant memory and
/// wait-free `record` from any number of threads.  Exact min/max ride
/// alongside the buckets so extreme quantiles of a tiny sample (p99 of
/// a 3-request run) can be clamped to observed reality instead of a
/// bucket midpoint.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    min_us: AtomicU64,
    max_us: AtomicU64,
}

const HIST_BUCKETS: usize = 512;

fn hist_index(us: u64) -> usize {
    if us < 8 {
        return us as usize;
    }
    let msb = 63 - u64::from(us.leading_zeros());
    (((msb << 3) | ((us >> (msb - 3)) & 7)) as usize).min(HIST_BUCKETS - 1)
}

fn hist_value_us(idx: usize) -> f64 {
    if idx < 8 {
        return idx as f64;
    }
    let msb = (idx >> 3) as u64;
    let sub = (idx & 7) as u64;
    let lo = (1u64 << msb) | (sub << (msb - 3));
    let width = 1u64 << (msb - 3);
    lo as f64 + width as f64 / 2.0
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            min_us: AtomicU64::new(u64::MAX),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[hist_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.min_us.fetch_min(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact recorded-microsecond total (the mean's numerator).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Snapshot of the raw bucket counters, for merge-losslessness tests
    /// and external aggregation.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Fold another histogram into this one: buckets, count, and sum add;
    /// min/max combine by min/max.  Every derived statistic (count, sum,
    /// min, max, every bucket — hence every quantile) of the merged
    /// histogram equals what a single shared histogram fed the union of
    /// samples would report, so per-shard histograms merged at scrape
    /// time lose nothing.  An empty `other` is a no-op: its `min_us`
    /// sentinel (`u64::MAX`) cannot lower an existing minimum.
    pub fn merge_from(&self, other: &LatencyHistogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = src.load(Ordering::Relaxed);
            if n != 0 {
                dst.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min_us.fetch_min(other.min_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Exact smallest recorded latency in ms (0.0 if empty).
    pub fn min_ms(&self) -> f64 {
        let min = self.min_us.load(Ordering::Relaxed);
        if min == u64::MAX {
            return 0.0;
        }
        min as f64 / 1e3
    }

    /// Exact largest recorded latency in ms (0.0 if empty).
    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }

    /// Latency at quantile `q` in [0, 1], in milliseconds (0.0 if empty).
    /// Clamped to the exact observed [min, max], so a quantile of a
    /// small sample never reads outside what actually happened.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        // Snapshot the buckets once and derive the target from that same
        // snapshot: concurrent `record_us` calls (bucket and count are
        // independent Relaxed atomics) can otherwise make the scan fall
        // off the end and report the top bucket.
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let n: u64 = counts.iter().sum();
        if n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        // The extreme order statistics are known exactly; everything in
        // between comes from the bucket scan, clamped to [min, max].
        if target >= n {
            return self.max_ms().max(self.min_ms());
        }
        if target == 1 {
            return self.min_ms();
        }
        let mut seen = 0u64;
        let mut raw = hist_value_us(HIST_BUCKETS - 1) / 1e3;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                raw = hist_value_us(i) / 1e3;
                break;
            }
        }
        let (min, max) = (self.min_ms(), self.max_ms());
        raw.clamp(min, max.max(min))
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("count", Json::from(self.count())),
            ("mean_ms", Json::from(self.mean_ms())),
            ("min_ms", Json::from(self.min_ms())),
            ("p50_ms", Json::from(self.quantile_ms(0.50))),
            ("p95_ms", Json::from(self.quantile_ms(0.95))),
            ("p99_ms", Json::from(self.quantile_ms(0.99))),
            ("max_ms", Json::from(self.max_ms())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates() {
        let m = Metrics::new();
        m.record("a", Duration::from_millis(2), Duration::ZERO, Duration::ZERO);
        m.record("a", Duration::from_millis(3), Duration::from_millis(1), Duration::ZERO);
        let s = m.snapshot();
        assert_eq!(s["a"].firings, 2);
        assert_eq!(s["a"].busy, Duration::from_millis(5));
        assert_eq!(s["a"].blocked_in, Duration::from_millis(1));
    }

    #[test]
    fn report_rates() {
        let mut actors = BTreeMap::new();
        actors.insert(
            "x".to_string(),
            ActorStats { firings: 10, busy: Duration::from_millis(50), ..Default::default() },
        );
        let r = RunReport {
            device: "n2".into(),
            wall: Duration::from_millis(200),
            frames: 10,
            actors,
        };
        assert!((r.ms_per_frame() - 20.0).abs() < 1e-9);
        assert!((r.busy_ms_per_frame() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn report_json_shape() {
        let r = RunReport {
            device: "d".into(),
            wall: Duration::from_millis(10),
            frames: 1,
            actors: BTreeMap::new(),
        };
        let j = r.to_json();
        assert_eq!(j.get("device").unwrap().str().unwrap(), "d");
        assert_eq!(j.get("frames").unwrap().int().unwrap(), 1);
    }

    #[test]
    fn histogram_quantiles_are_order_of_magnitude_right() {
        let h = LatencyHistogram::new();
        for _ in 0..900 {
            h.record(Duration::from_micros(1_000)); // 1 ms
        }
        for _ in 0..100 {
            h.record(Duration::from_micros(100_000)); // 100 ms tail
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ms(0.50);
        let p99 = h.quantile_ms(0.99);
        assert!((0.9..=1.2).contains(&p50), "p50 {p50}");
        assert!((85.0..=115.0).contains(&p99), "p99 {p99}");
        assert!(h.mean_ms() > p50 && h.mean_ms() < p99);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().int().unwrap(), 1000);
    }

    #[test]
    fn histogram_empty_and_small_values() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ms(0.5), 0.0);
        h.record_us(0);
        h.record_us(3);
        assert!(h.quantile_ms(1.0) <= 0.004);
    }

    #[test]
    fn histogram_bucket_boundaries_are_pinned() {
        // Exact region: one bucket per microsecond below 8 µs.
        for us in 0..8u64 {
            assert_eq!(hist_index(us), us as usize);
            assert_eq!(hist_value_us(us as usize), us as f64);
        }
        // First log-linear bucket: 8 µs has msb 3, sub-bucket 0 →
        // index (3<<3)|0 = 24, covering [8, 9) with midpoint 8.5.
        assert_eq!(hist_index(8), 24);
        assert_eq!(hist_value_us(24), 8.5);
        assert_eq!(hist_index(9), 25, "1 µs sub-bucket width below 16 µs");
        // 1000 µs: msb 9, sub = (1000 >> 6) & 7 = 7 → index 79,
        // bucket [960, 1024) with midpoint 992.
        assert_eq!(hist_index(1000), (9 << 3) | 7);
        assert_eq!(hist_value_us((9 << 3) | 7), 992.0);
        // Power-of-two edges land in sub-bucket 0 of the next octave.
        assert_eq!(hist_index(1024), 10 << 3);
        assert_eq!(hist_index(1023), (9 << 3) | 7);
        // Relative error bound: bucket width is 2^(msb-3), i.e. ≤ 1/8
        // of the value — midpoint error ≤ ~6%.
        for us in [100u64, 5_000, 123_456, 10_000_000] {
            let mid = hist_value_us(hist_index(us));
            assert!((mid - us as f64).abs() / us as f64 < 0.0625, "{us} -> {mid}");
        }
        // Saturating top bucket.
        assert_eq!(hist_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_tracks_exact_min_max_and_clamps_quantiles() {
        let h = LatencyHistogram::new();
        assert_eq!(h.min_ms(), 0.0, "empty histogram reads neutral");
        assert_eq!(h.max_ms(), 0.0);
        // Three samples: bucket-midpoint p99 would overshoot the real
        // maximum; the exact-max clamp pins it.
        h.record_us(1_000);
        h.record_us(2_000);
        h.record_us(3_000);
        assert_eq!(h.min_ms(), 1.0);
        assert_eq!(h.max_ms(), 3.0);
        assert_eq!(h.quantile_ms(0.99), 3.0, "p99 of 3 samples is the exact max");
        assert_eq!(h.quantile_ms(0.0), 1.0, "p0 clamps to the exact min");
        let j = h.to_json();
        assert_eq!(j.get("min_ms").unwrap().num().unwrap(), 1.0);
        assert_eq!(j.get("max_ms").unwrap().num().unwrap(), 3.0);
    }

    #[test]
    fn histogram_bucket_index_monotone() {
        let mut last = 0usize;
        for us in [0u64, 1, 7, 8, 9, 100, 1000, 65_535, 1 << 30, u64::MAX] {
            let idx = hist_index(us);
            assert!(idx >= last, "index not monotone at {us}");
            last = idx;
        }
        assert!(hist_index(u64::MAX) < HIST_BUCKETS);
    }

    #[test]
    fn wire_counters_ratio() {
        let w = WireCounters::new();
        assert_eq!(w.compression_ratio(), 1.0, "idle gauge is neutral");
        // One int8 inference: 1041-byte request carrying a 4096-byte
        // f32-equivalent tensor, 141-byte f32 response.
        w.note_rx(1041, 4109);
        w.note_tx(141, 141);
        let r = w.compression_ratio();
        assert!(r > 3.5 && r < 4.0, "ratio {r}");
        let j = w.to_json();
        assert_eq!(j.get("bytes_rx").unwrap().int().unwrap(), 1041);
        assert_eq!(j.get("f32_equiv_rx").unwrap().int().unwrap(), 4109);
    }

    #[test]
    fn histogram_merge_is_lossless() {
        // Feed the same sample stream into one shared histogram and into
        // four "per-shard" histograms (round-robin), merge the shards,
        // and require bitwise agreement on count/sum/min/max and every
        // bucket — which implies agreement on every quantile.
        let shared = LatencyHistogram::new();
        let shards: Vec<LatencyHistogram> =
            (0..4).map(|_| LatencyHistogram::new()).collect();
        let mut rng = crate::util::rng::Rng::new(0x5ca1ab1e);
        for i in 0..10_000 {
            // Span the exact region, the log-linear region, and the tail.
            let us = match rng.below(4) {
                0 => rng.below(8) as u64,
                1 => rng.below(1 << 12) as u64,
                2 => rng.below(1 << 22) as u64,
                _ => 1 + (rng.next_u64() >> 24),
            };
            shared.record_us(us);
            shards[i % 4].record_us(us);
        }
        let merged = LatencyHistogram::new();
        for s in &shards {
            merged.merge_from(s);
        }
        assert_eq!(merged.count(), shared.count());
        assert_eq!(merged.sum_us(), shared.sum_us());
        assert_eq!(merged.min_ms(), shared.min_ms());
        assert_eq!(merged.max_ms(), shared.max_ms());
        assert_eq!(merged.bucket_counts(), shared.bucket_counts());
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(merged.quantile_ms(q), shared.quantile_ms(q), "q={q}");
        }
    }

    #[test]
    fn histogram_merge_with_empty_shard_is_identity() {
        let h = LatencyHistogram::new();
        h.record_us(250);
        h.record_us(90_000);
        let (min, max, count) = (h.min_ms(), h.max_ms(), h.count());
        h.merge_from(&LatencyHistogram::new());
        assert_eq!(h.min_ms(), min, "empty shard's u64::MAX sentinel must not leak");
        assert_eq!(h.max_ms(), max);
        assert_eq!(h.count(), count);
    }

    #[test]
    fn wire_counters_merge_is_lossless() {
        use crate::runtime::wire::SparseStats;
        let shared = WireCounters::new();
        let a = WireCounters::new();
        let b = WireCounters::new();
        for (i, w) in [(1u64, &a), (2, &b), (3, &a), (4, &b)] {
            w.note_tx(10 * i, 40 * i);
            w.note_rx(7 * i, 28 * i);
            w.note_sparse(SparseStats { elems: 1024, nnz: 200 + i as usize }, 350);
            shared.note_tx(10 * i, 40 * i);
            shared.note_rx(7 * i, 28 * i);
            shared.note_sparse(SparseStats { elems: 1024, nnz: 200 + i as usize }, 350);
        }
        let merged = WireCounters::new();
        merged.merge_from(&a);
        merged.merge_from(&b);
        for (m, s) in [
            (&merged.bytes_tx, &shared.bytes_tx),
            (&merged.bytes_rx, &shared.bytes_rx),
            (&merged.f32_equiv_tx, &shared.f32_equiv_tx),
            (&merged.f32_equiv_rx, &shared.f32_equiv_rx),
            (&merged.sparse_elems, &shared.sparse_elems),
            (&merged.sparse_nnz, &shared.sparse_nnz),
            (&merged.sparse_saved, &shared.sparse_saved),
        ] {
            assert_eq!(m.load(Ordering::Relaxed), s.load(Ordering::Relaxed));
        }
        assert_eq!(merged.compression_ratio(), shared.compression_ratio());
        assert_eq!(merged.achieved_sparsity(), shared.achieved_sparsity());
    }

    #[test]
    fn sparse_gauges_read_sparsity_and_savings() {
        use crate::runtime::wire::SparseStats;
        let w = WireCounters::new();
        assert_eq!(w.achieved_sparsity(), 0.0, "idle gauge is neutral");
        // One 1024-element payload shipping 256 coefficients in 393
        // bytes: 75% sparsity, (4 + 1024) - 393 bytes saved vs dense i8.
        w.note_sparse(SparseStats { elems: 1024, nnz: 256 }, 393);
        assert!((w.achieved_sparsity() - 0.75).abs() < 1e-12);
        assert_eq!(w.sparse_saved.load(Ordering::Relaxed), 1028 - 393);
        let j = w.to_json();
        assert_eq!(j.get("sparse_bytes_saved").unwrap().int().unwrap(), 635);
        assert!((j.get("achieved_sparsity").unwrap().num().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn zero_frames_is_nan() {
        let r = RunReport {
            device: "d".into(),
            wall: Duration::from_millis(10),
            frames: 0,
            actors: BTreeMap::new(),
        };
        assert!(r.ms_per_frame().is_nan());
    }
}
