//! Device simulation (substitutes the paper's Table-I platforms).
//!
//! One host reproduces the heterogeneous endpoint/server timing by a
//! per-platform cost model: every actor firing runs its *real* kernel
//! (XLA executable or plain Rust) and is then padded by sleeping the
//! residual up to the platform's target cost for that actor.  A counting
//! semaphore with `cores` permits is held across the firing (and across
//! TX/RX socket work), so a single-core platform (Atom N270) serializes
//! compute with communication while multicore platforms (N2, i7) overlap —
//! the behaviour difference that shapes Fig. 4 vs Fig. 5.
//!
//! Cost resolution order: explicit per-actor table entry, else
//! `flops / gflops` if the actor has a FLOPs estimate, else 0 (no padding;
//! "native host speed" — the i7-in-real-mode case).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: String,
    /// Per-actor target cost in milliseconds (profile-calibrated).
    pub cost_ms: BTreeMap<String, f64>,
    /// Fallback effective compute throughput (GFLOP/s); 0 disables.
    pub gflops: f64,
    /// Number of cores: bounds concurrent firings + socket work.
    pub cores: usize,
    /// Accelerator slots: compute actors additionally serialize through
    /// this many "GPU queues" (the paper's devices run DNN layers
    /// sequentially on one accelerator while TX/RX overlaps on the CPU).
    pub accel_slots: usize,
    /// Global time scale applied to all targets (bench fast-runs).
    pub time_scale: f64,
    /// Pad firings up to the cost-model target (sleep the residual
    /// after the real kernel ran).  Since actors execute real compute,
    /// the cost table is calibration-only; `false` (CLI `--no-pad`)
    /// disables padding entirely and measures raw kernel speed.
    pub padding: bool,
}

impl DeviceModel {
    /// "Native" device: no padding, as many cores as the host.
    pub fn native(name: &str) -> Self {
        DeviceModel {
            name: name.to_string(),
            cost_ms: BTreeMap::new(),
            gflops: 0.0,
            cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8),
            accel_slots: usize::MAX / 2, // native host: no accelerator model
            time_scale: 1.0,
            padding: true,
        }
    }

    pub fn with_cost(mut self, actor: &str, ms: f64) -> Self {
        self.cost_ms.insert(actor.to_string(), ms);
        self
    }

    /// Toggle residual cost padding (CLI `--no-pad`).
    pub fn with_padding(mut self, on: bool) -> Self {
        self.padding = on;
        self
    }

    /// Target cost for an actor firing, in milliseconds (already scaled).
    pub fn target_ms(&self, actor: &str, flops: u64) -> f64 {
        let base = if let Some(&ms) = self.cost_ms.get(actor) {
            ms
        } else if self.gflops > 0.0 && flops > 0 {
            flops as f64 / (self.gflops * 1e6)
        } else {
            0.0
        };
        base * self.time_scale
    }

    /// Parse every field except the cost table — shared by
    /// [`DeviceModel::from_json`] (flat `cost_ms` map) and the platform
    /// configs loader (per-model nested `cost_ms` tables), so a new
    /// field added here reaches both schemas.
    pub fn base_from_json(name: &str, v: &Json) -> anyhow::Result<Self> {
        Ok(DeviceModel {
            name: name.to_string(),
            cost_ms: BTreeMap::new(),
            gflops: v.opt("gflops").map(|j| j.num()).transpose()?.unwrap_or(0.0),
            cores: v.opt("cores").map(|j| j.usize()).transpose()?.unwrap_or(8),
            accel_slots: v.opt("accel_slots").map(|j| j.usize()).transpose()?.unwrap_or(1),
            time_scale: 1.0,
            padding: v.opt("padding").map(|j| j.bool()).transpose()?.unwrap_or(true),
        })
    }

    /// Parse from a flat `cost_ms` schema (`{"actor": ms, ...}`).
    pub fn from_json(name: &str, v: &Json) -> anyhow::Result<Self> {
        let mut d = Self::base_from_json(name, v)?;
        if let Some(tbl) = v.opt("cost_ms") {
            for (k, val) in tbl.obj()? {
                d.cost_ms.insert(k.clone(), val.num()?);
            }
        }
        Ok(d)
    }
}

/// Counting semaphore modelling the platform's cores.
#[derive(Debug)]
pub struct CoreSet {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl CoreSet {
    pub fn new(cores: usize) -> Self {
        CoreSet { permits: Mutex::new(cores.max(1)), cv: Condvar::new() }
    }

    pub fn acquire(&self) -> CoreGuard<'_> {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        CoreGuard { set: self }
    }
}

pub struct CoreGuard<'a> {
    set: &'a CoreSet,
}

impl Drop for CoreGuard<'_> {
    fn drop(&mut self) {
        let mut p = self.set.permits.lock().unwrap();
        *p += 1;
        drop(p);
        self.set.cv.notify_one();
    }
}

/// Pad a firing that took `elapsed` up to `target_ms` by sleeping.
pub fn pad_to_target(elapsed: Duration, target_ms: f64) {
    let target = Duration::from_secs_f64(target_ms.max(0.0) / 1e3);
    if target > elapsed {
        std::thread::sleep(target - elapsed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn cost_table_takes_precedence_over_gflops() {
        let d = DeviceModel {
            name: "n2".into(),
            cost_ms: BTreeMap::from([("l1".to_string(), 6.2)]),
            gflops: 10.0,
            cores: 6,
            accel_slots: 1,
            time_scale: 1.0,
            padding: true,
        };
        assert_eq!(d.target_ms("l1", 1_000_000_000), 6.2);
        // Fallback: 1 GFLOP at 10 GFLOP/s = 100 ms.
        assert!((d.target_ms("lx", 1_000_000_000) - 100.0).abs() < 1e-9);
        assert_eq!(d.target_ms("ly", 0), 0.0);
    }

    #[test]
    fn time_scale_scales_targets() {
        let mut d = DeviceModel::native("x").with_cost("a", 10.0);
        d.time_scale = 0.5;
        assert_eq!(d.target_ms("a", 0), 5.0);
    }

    #[test]
    fn native_device_never_pads() {
        let d = DeviceModel::native("host");
        assert_eq!(d.target_ms("anything", 123456), 0.0);
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"cores": 1, "gflops": 0.4, "cost_ms": {"l1": 123.0}}"#,
        )
        .unwrap();
        let d = DeviceModel::from_json("n270", &j).unwrap();
        assert_eq!(d.cores, 1);
        assert_eq!(d.target_ms("l1", 0), 123.0);
        assert!(d.gflops > 0.0);
    }

    #[test]
    fn padding_flag_parses_and_toggles() {
        let j = Json::parse(r#"{"cores": 2, "padding": false}"#).unwrap();
        assert!(!DeviceModel::from_json("x", &j).unwrap().padding);
        let d = DeviceModel::native("y");
        assert!(d.padding, "padding defaults on");
        assert!(!d.with_padding(false).padding);
    }

    #[test]
    fn pad_to_target_sleeps_residual() {
        let t0 = Instant::now();
        pad_to_target(Duration::from_millis(0), 20.0);
        assert!(t0.elapsed() >= Duration::from_millis(19));
        let t1 = Instant::now();
        pad_to_target(Duration::from_millis(30), 10.0); // already over
        assert!(t1.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn coreset_limits_concurrency() {
        let set = Arc::new(CoreSet::new(1));
        let concurrent = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let (s, c, p) = (set.clone(), concurrent.clone(), peak.clone());
                std::thread::spawn(move || {
                    let _g = s.acquire();
                    let now = c.fetch_add(1, Ordering::SeqCst) + 1;
                    p.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    c.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(peak.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn coreset_multicore_allows_overlap() {
        let set = Arc::new(CoreSet::new(4));
        let concurrent = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let (s, c, p) = (set.clone(), concurrent.clone(), peak.clone());
                std::thread::spawn(move || {
                    let _g = s.acquire();
                    let now = c.fetch_add(1, Ordering::SeqCst) + 1;
                    p.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(20));
                    c.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) > 1);
    }
}
