//! Event-driven I/O core: an epoll-based reactor with a hierarchical
//! timer wheel, written directly against the OS (no mio/tokio — the
//! build is offline and dependency-free, mirroring how
//! `platform::affinity` declares `sched_setaffinity` itself).
//!
//! The serving stack (`crate::server`) registers nonblocking sockets
//! here and runs every connection as a state machine on ONE reactor
//! thread instead of spawning reader/writer threads per session — the
//! DEFER/Edge-PRUNE follow-up observation that edge throughput lives or
//! dies on the communication layer.  The pieces:
//!
//! * [`Poller`] — interest registration + ready-queue dispatch.  Linux
//!   uses `epoll` (level-triggered); other Unixes fall back to
//!   `poll(2)`.  Tokens are plain `u64`s chosen by the caller
//!   (connection ids, reserved listener/wake ids);
//! * [`TimerWheel`] — a 4-level × 64-slot hierarchical wheel at 1 ms
//!   resolution.  Heartbeat reaping, handshake deadlines, and idle
//!   timeouts all live here, so an idle server sleeps in `epoll_wait`
//!   instead of polling (`advance` takes the current `Instant`, which
//!   also makes the wheel testable on virtual time);
//! * [`Reactor`] — the composition: poller + wake channel.  Worker
//!   threads call [`WakeHandle::wake`] (an eventfd-style self-pipe
//!   built on a `UnixStream` pair) to interrupt the sleeping loop when
//!   completions are ready;
//! * [`ByteBuf`] — the consume-from-the-front byte buffer under the
//!   partial-frame codecs (`server::protocol::decode_frame`,
//!   `runtime::net::FrameDecoder`).
//!
//! Modeled on the `mini-async-runtime` related repo's reactor/parking
//! split, minus futures: connection state machines are explicit, so no
//! executor is needed.

use crate::runtime::trace::{self, Stage};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------- events

/// What a registered fd should be watched for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    pub const BOTH: Interest = Interest { readable: true, writable: true };
}

/// One readiness event.  Error/hangup conditions are folded into
/// `readable` (a read will surface the error/EOF), matching how the
/// connection state machines consume them.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

// ------------------------------------------------------------ sys: epoll

#[cfg(target_os = "linux")]
mod sys {
    use super::{Event, Interest};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    // glibc packs epoll_event on x86-64 (the kernel ABI there has no
    // padding between `events` and the 64-bit data union).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// Level-triggered epoll instance.
    pub struct Poller {
        epfd: RawFd,
    }

    const MAX_EVENTS: usize = 256;

    fn mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.readable {
            // RDHUP only together with read interest: a connection that
            // has deliberately stopped reading (backpressure pause,
            // draining) must not be woken level-triggered for a peer
            // half-close it is not going to consume — that would spin
            // the reactor.  (EPOLLERR/EPOLLHUP are always reported
            // regardless of the mask and surface through the write
            // path, which is still armed whenever output is pending.)
            m |= EPOLLIN | EPOLLRDHUP;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, ev: Option<EpollEvent>) -> io::Result<()> {
            let mut ev = ev;
            let p = match ev.as_mut() {
                Some(e) => e as *mut EpollEvent,
                None => std::ptr::null_mut(),
            };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, p) };
            if rc != 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, Some(EpollEvent { events: mask(interest), data: token }))
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, Some(EpollEvent { events: mask(interest), data: token }))
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, None)
        }

        /// Wait for readiness; `None` timeout blocks indefinitely.
        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let ms: i32 = match timeout {
                None => -1,
                // Round up so a 500 µs timer does not busy-spin at 0 ms.
                Some(d) => d.as_micros().div_ceil(1000).min(i32::MAX as u128) as i32,
            };
            let mut buf = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
            let n = loop {
                let rc =
                    unsafe { epoll_wait(self.epfd, buf.as_mut_ptr(), MAX_EVENTS as i32, ms) };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for ev in buf.iter().take(n).copied() {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLHUP | EPOLLRDHUP | EPOLLERR) != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------- sys: poll fallback

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::{Event, Interest};
    use std::collections::BTreeMap;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::sync::Mutex;
    use std::time::Duration;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }

    /// `poll(2)`-based fallback for non-Linux Unixes: interest lives in
    /// a map rebuilt into a pollfd array per wait.  O(n) per wake, fine
    /// for the session counts a dev laptop sees.
    pub struct Poller {
        interests: Mutex<BTreeMap<RawFd, (u64, Interest)>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { interests: Mutex::new(BTreeMap::new()) })
        }

        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.interests.lock().unwrap().insert(fd, (token, interest));
            Ok(())
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.interests.lock().unwrap().insert(fd, (token, interest));
            Ok(())
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.interests.lock().unwrap().remove(&fd);
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let (mut fds, tokens): (Vec<PollFd>, Vec<u64>) = {
                let map = self.interests.lock().unwrap();
                let mut fds = Vec::with_capacity(map.len());
                let mut tokens = Vec::with_capacity(map.len());
                for (&fd, &(token, interest)) in map.iter() {
                    let mut events = 0i16;
                    if interest.readable {
                        events |= POLLIN;
                    }
                    if interest.writable {
                        events |= POLLOUT;
                    }
                    fds.push(PollFd { fd, events, revents: 0 });
                    tokens.push(token);
                }
                (fds, tokens)
            };
            let ms: i32 = match timeout {
                None => -1,
                Some(d) => d.as_micros().div_ceil(1000).min(i32::MAX as u128) as i32,
            };
            let n = loop {
                let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u32, ms) };
                if rc >= 0 {
                    break rc;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            if n == 0 {
                return Ok(());
            }
            for (pfd, &token) in fds.iter().zip(tokens.iter()) {
                let bits = pfd.revents;
                if bits == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: bits & (POLLIN | POLLHUP | POLLERR) != 0,
                    writable: bits & (POLLOUT | POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

pub use sys::Poller;

// ----------------------------------------------------------- timer wheel

const SLOTS: usize = 64;
const LEVELS: usize = 4;
/// Wheel resolution: one tick per millisecond.
pub const TICK: Duration = Duration::from_millis(1);

struct TimerEntry<T> {
    id: u64,
    /// Absolute expiry in ticks since the wheel's start instant.
    expiry: u64,
    token: T,
}

/// Hierarchical timing wheel: 4 levels × 64 slots at 1 ms per tick
/// (level spans: 64 ms, ~4 s, ~4.4 min, ~4.7 h; longer delays clamp to
/// the top-level horizon).  Insert/cancel are O(1); `advance` cascades
/// higher levels down as their boundaries pass.  All time flows in
/// through `Instant` parameters so tests can drive the wheel on virtual
/// time.
pub struct TimerWheel<T> {
    start: Instant,
    /// Ticks fully processed by `advance` so far.
    now_tick: u64,
    next_id: u64,
    /// Ids scheduled and not yet fired/cancelled.
    scheduled: std::collections::HashSet<u64>,
    /// Cancelled ids whose entries still sit in a slot (lazily dropped).
    cancelled: std::collections::HashSet<u64>,
    levels: [[Vec<TimerEntry<T>>; SLOTS]; LEVELS],
}

impl<T> TimerWheel<T> {
    pub fn new(start: Instant) -> Self {
        TimerWheel {
            start,
            now_tick: 0,
            next_id: 1,
            scheduled: std::collections::HashSet::new(),
            cancelled: std::collections::HashSet::new(),
            levels: std::array::from_fn(|_| std::array::from_fn(|_| Vec::new())),
        }
    }

    fn ticks_at(&self, now: Instant) -> u64 {
        now.saturating_duration_since(self.start).as_millis() as u64
    }

    /// Schedule `token` to fire `delay` from `now`; returns a cancel id.
    /// Sub-tick delays round up to one tick.
    pub fn insert(&mut self, now: Instant, delay: Duration, token: T) -> u64 {
        let delay_ticks = (delay.as_micros().div_ceil(1000) as u64).max(1);
        let expiry = (self.ticks_at(now).max(self.now_tick) + delay_ticks).max(self.now_tick + 1);
        let id = self.next_id;
        self.next_id += 1;
        self.scheduled.insert(id);
        let entry = TimerEntry { id, expiry, token };
        self.place(self.now_tick, entry);
        id
    }

    /// Slot an entry relative to `basis` (the tick currently being
    /// processed, or `now_tick` on insert).
    fn place(&mut self, basis: u64, entry: TimerEntry<T>) {
        let delta = entry.expiry.saturating_sub(basis);
        let (level, index) = if delta < SLOTS as u64 {
            // An already-due entry (cascade edge) lands in the slot
            // being drained right now.
            (0, entry.expiry.max(basis) % SLOTS as u64)
        } else if delta < (SLOTS * SLOTS) as u64 {
            (1, (entry.expiry / SLOTS as u64) % SLOTS as u64)
        } else if delta < (SLOTS * SLOTS * SLOTS) as u64 {
            (2, (entry.expiry / (SLOTS * SLOTS) as u64) % SLOTS as u64)
        } else {
            (3, (entry.expiry / (SLOTS * SLOTS * SLOTS) as u64) % SLOTS as u64)
        };
        self.levels[level][index as usize].push(entry);
    }

    /// Fire everything due at or before `now`, pushing tokens in expiry
    /// order onto `expired`.
    pub fn advance(&mut self, now: Instant, expired: &mut Vec<T>) {
        let before = expired.len();
        let t0 = if trace::enabled() { trace::now_us() } else { 0 };
        self.advance_inner(now, expired);
        // Flight-recorder breadcrumb: how long the wheel walk took when
        // it actually fired something (process-local, not per-request).
        if t0 != 0 && expired.len() > before {
            trace::record(
                trace::LOCAL,
                0,
                Stage::TimerFire,
                (expired.len() - before) as u32,
                t0,
                trace::now_us(),
            );
        }
    }

    fn advance_inner(&mut self, now: Instant, expired: &mut Vec<T>) {
        let target = self.ticks_at(now);
        if self.scheduled.is_empty() {
            // Nothing can fire; skip the walk (and drop stale tombstones
            // whose slots will never drain before reuse matters).
            self.now_tick = target;
            self.cancelled.clear();
            return;
        }
        while self.now_tick < target {
            let t = self.now_tick + 1;
            // Cascade boundaries: bring the covering higher-level slot
            // down before draining this tick.
            if t % SLOTS as u64 == 0 {
                self.cascade(1, t);
                if t % (SLOTS * SLOTS) as u64 == 0 {
                    self.cascade(2, t);
                    if t % (SLOTS * SLOTS * SLOTS) as u64 == 0 {
                        self.cascade(3, t);
                    }
                }
            }
            let slot = (t % SLOTS as u64) as usize;
            if !self.levels[0][slot].is_empty() {
                let entries = std::mem::take(&mut self.levels[0][slot]);
                for entry in entries {
                    if entry.expiry > t {
                        // A later rotation's entry sharing the slot.
                        self.levels[0][slot].push(entry);
                    } else if self.cancelled.remove(&entry.id) {
                        // tombstone consumed
                    } else if self.scheduled.remove(&entry.id) {
                        expired.push(entry.token);
                    }
                }
            }
            self.now_tick = t;
            if self.scheduled.is_empty() {
                self.now_tick = target;
                self.cancelled.clear();
                return;
            }
        }
    }

    fn cascade(&mut self, level: usize, t: u64) {
        let div = (SLOTS as u64).pow(level as u32);
        let index = ((t / div) % SLOTS as u64) as usize;
        let entries = std::mem::take(&mut self.levels[level][index]);
        for entry in entries {
            if self.cancelled.remove(&entry.id) {
                continue;
            }
            self.place(t, entry);
        }
    }

    /// Unschedule a timer; `false` if it already fired or was cancelled.
    pub fn cancel(&mut self, id: u64) -> bool {
        if self.scheduled.remove(&id) {
            self.cancelled.insert(id);
            true
        } else {
            false
        }
    }

    /// Live (scheduled, uncancelled) timer count.
    pub fn len(&self) -> usize {
        self.scheduled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scheduled.is_empty()
    }

    /// How long the event loop may sleep before the next timer could
    /// fire.  Exact for timers already cascaded to level 0; timers still
    /// on higher levels bound the sleep to one level-0 rotation (64 ms),
    /// which keeps the loop O(1) instead of scanning entries.  `None`
    /// when no timer is scheduled (sleep indefinitely).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        if self.scheduled.is_empty() {
            return None;
        }
        let mut ahead = SLOTS as u64;
        for i in 1..=SLOTS as u64 {
            if !self.levels[0][((self.now_tick + i) % SLOTS as u64) as usize].is_empty() {
                ahead = i;
                break;
            }
        }
        let deadline = self.now_tick + ahead;
        let now_ticks = self.ticks_at(now);
        Some(Duration::from_millis(deadline.saturating_sub(now_ticks)))
    }
}

// ------------------------------------------------------------------ wake

/// Cross-thread wake-up for a sleeping reactor: an eventfd-style
/// self-pipe built on a `UnixStream` pair (portable across Unixes, no
/// extra FFI).  Cloneable and cheap; coalesces naturally — once the
/// pipe holds a byte, further wakes are no-ops until the reactor
/// drains it.
#[derive(Clone)]
pub struct WakeHandle {
    tx: Arc<UnixStream>,
}

impl WakeHandle {
    /// Interrupt the reactor's `poll`.  Infallible by design: a full
    /// pipe already guarantees a pending wake-up, and a closed reactor
    /// no longer cares.
    pub fn wake(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

// --------------------------------------------------------------- reactor

/// Token `poll` reserves for the wake channel; user tokens must differ.
pub const WAKE_TOKEN: u64 = u64::MAX;

/// Poller + wake channel: the substrate an event loop builds on.  The
/// caller owns its fds, its token namespace, and (optionally) a
/// [`TimerWheel`] for deadline bookkeeping.
pub struct Reactor {
    poller: Poller,
    wake_rx: UnixStream,
    wake_tx: Arc<UnixStream>,
}

impl Reactor {
    pub fn new() -> Result<Reactor> {
        let (tx, rx) = UnixStream::pair().context("creating reactor wake channel")?;
        tx.set_nonblocking(true).context("wake tx nonblocking")?;
        rx.set_nonblocking(true).context("wake rx nonblocking")?;
        let poller = Poller::new().context("creating poller")?;
        poller
            .register(rx.as_raw_fd(), WAKE_TOKEN, Interest::READ)
            .context("registering wake channel")?;
        Ok(Reactor { poller, wake_rx: rx, wake_tx: Arc::new(tx) })
    }

    /// A handle other threads use to interrupt `poll`.
    pub fn waker(&self) -> WakeHandle {
        WakeHandle { tx: self.wake_tx.clone() }
    }

    pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        self.poller
            .register(fd, token, interest)
            .with_context(|| format!("registering fd {fd}"))
    }

    pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        self.poller.modify(fd, token, interest).with_context(|| format!("rearming fd {fd}"))
    }

    pub fn deregister(&self, fd: RawFd) -> Result<()> {
        self.poller.deregister(fd).with_context(|| format!("deregistering fd {fd}"))
    }

    /// Wait for readiness or `timeout`.  Wake-channel events are
    /// consumed internally; returns whether a wake arrived (the caller
    /// then checks its cross-thread queues).
    pub fn poll(&self, events: &mut Vec<Event>, timeout: Option<Duration>) -> Result<bool> {
        self.poller.wait(events, timeout).context("polling for readiness")?;
        let mut woken = false;
        events.retain(|e| {
            if e.token == WAKE_TOKEN {
                woken = true;
                false
            } else {
                true
            }
        });
        if woken {
            let mut buf = [0u8; 64];
            loop {
                match (&self.wake_rx).read(&mut buf) {
                    Ok(0) => break,                // peer gone; stop draining
                    Ok(_) => continue,             // keep draining coalesced wakes
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break,               // WouldBlock: drained
                }
            }
        }
        Ok(woken)
    }
}

// --------------------------------------------------------------- bytebuf

/// Grow-at-the-back, consume-at-the-front byte buffer for partial-frame
/// codecs.  Consumption is an index bump; the occasional compaction
/// keeps memory bounded without shifting bytes per frame.
#[derive(Debug, Default)]
pub struct ByteBuf {
    buf: Vec<u8>,
    start: usize,
}

impl ByteBuf {
    pub fn new() -> ByteBuf {
        ByteBuf::default()
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes, oldest first.
    pub fn peek(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    pub fn len(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.buf.len()
    }

    /// Drop the oldest `n` bytes (they were decoded or written out).
    pub fn consume(&mut self, n: usize) {
        assert!(n <= self.len(), "consume({n}) past end of buffer");
        self.start += n;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start >= 4096 && self.start * 2 >= self.buf.len() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.start = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytebuf_consume_and_compact() {
        let mut b = ByteBuf::new();
        b.extend(&[1, 2, 3, 4, 5]);
        assert_eq!(b.peek(), &[1, 2, 3, 4, 5]);
        b.consume(2);
        assert_eq!(b.peek(), &[3, 4, 5]);
        assert_eq!(b.len(), 3);
        b.extend(&[6]);
        assert_eq!(b.peek(), &[3, 4, 5, 6]);
        b.consume(4);
        assert!(b.is_empty());
        // Large-churn path: compaction keeps the front index bounded.
        for round in 0..100 {
            b.extend(&vec![round as u8; 100]);
            b.consume(100);
        }
        assert!(b.is_empty());
    }

    #[test]
    #[should_panic(expected = "past end")]
    fn bytebuf_overconsume_panics() {
        let mut b = ByteBuf::new();
        b.extend(&[1]);
        b.consume(2);
    }

    #[test]
    fn wheel_fires_in_order_on_virtual_time() {
        let t0 = Instant::now();
        let mut w: TimerWheel<&'static str> = TimerWheel::new(t0);
        w.insert(t0, Duration::from_millis(30), "b");
        w.insert(t0, Duration::from_millis(10), "a");
        w.insert(t0, Duration::from_millis(300), "c");
        assert_eq!(w.len(), 3);
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_millis(5), &mut fired);
        assert!(fired.is_empty());
        w.advance(t0 + Duration::from_millis(40), &mut fired);
        assert_eq!(fired, vec!["a", "b"], "both short timers fire, in expiry order");
        fired.clear();
        // "c" sits on level 1 until its cascade boundary passes.
        w.advance(t0 + Duration::from_millis(299), &mut fired);
        assert!(fired.is_empty());
        w.advance(t0 + Duration::from_millis(301), &mut fired);
        assert_eq!(fired, vec!["c"]);
        assert!(w.is_empty());
    }

    #[test]
    fn wheel_cancel_suppresses_firing() {
        let t0 = Instant::now();
        let mut w: TimerWheel<u32> = TimerWheel::new(t0);
        let a = w.insert(t0, Duration::from_millis(5), 1);
        let b = w.insert(t0, Duration::from_millis(5), 2);
        assert!(w.cancel(a));
        assert!(!w.cancel(a), "double cancel is refused");
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_millis(10), &mut fired);
        assert_eq!(fired, vec![2]);
        assert!(!w.cancel(b), "cancelling a fired timer is refused");
    }

    #[test]
    fn wheel_long_delay_cascades_through_levels() {
        let t0 = Instant::now();
        let mut w: TimerWheel<u8> = TimerWheel::new(t0);
        // Level 2 territory: > 64*64 ms.
        w.insert(t0, Duration::from_millis(5000), 9);
        let mut fired = Vec::new();
        w.advance(t0 + Duration::from_millis(4999), &mut fired);
        assert!(fired.is_empty());
        w.advance(t0 + Duration::from_millis(5001), &mut fired);
        assert_eq!(fired, vec![9]);
    }

    #[test]
    fn wheel_deadline_tracks_nearest_timer() {
        let t0 = Instant::now();
        let mut w: TimerWheel<u8> = TimerWheel::new(t0);
        assert!(w.next_deadline(t0).is_none(), "no timers -> sleep forever");
        w.insert(t0, Duration::from_millis(10), 1);
        let d = w.next_deadline(t0).unwrap();
        assert!(d <= Duration::from_millis(10), "deadline {d:?} past the timer");
        assert!(d >= Duration::from_millis(9));
        // A long timer bounds the sleep to one rotation, never forever.
        let mut w2: TimerWheel<u8> = TimerWheel::new(t0);
        w2.insert(t0, Duration::from_secs(30), 2);
        let d2 = w2.next_deadline(t0).unwrap();
        assert!(d2 <= Duration::from_millis(SLOTS as u64));
    }

    #[test]
    fn wheel_reinsert_from_fire_keeps_period() {
        // The recurring-reap pattern: re-insert on every fire.
        let t0 = Instant::now();
        let mut w: TimerWheel<&'static str> = TimerWheel::new(t0);
        w.insert(t0, Duration::from_millis(20), "tick");
        let mut count = 0;
        let mut fired = Vec::new();
        for step in 1..=100u64 {
            let now = t0 + Duration::from_millis(step * 5);
            w.advance(now, &mut fired);
            for _ in fired.drain(..) {
                count += 1;
                w.insert(now, Duration::from_millis(20), "tick");
            }
        }
        // 500 ms of virtual time at a 20 ms period.
        assert!((20..=27).contains(&count), "fired {count} times");
    }

    #[test]
    fn reactor_wake_interrupts_poll() {
        let reactor = Reactor::new().unwrap();
        let waker = reactor.waker();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
        });
        let mut events = Vec::new();
        let t0 = Instant::now();
        let woken = reactor.poll(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(woken, "wake handle interrupted the sleep");
        assert!(events.is_empty(), "wake events are internal");
        assert!(t0.elapsed() < Duration::from_secs(4), "did not sleep out the timeout");
        h.join().unwrap();
        // Coalesced wakes drain in one poll.
        reactor.waker().wake();
        reactor.waker().wake();
        assert!(reactor.poll(&mut events, Some(Duration::from_millis(100))).unwrap());
        assert!(!reactor.poll(&mut events, Some(Duration::from_millis(10))).unwrap());
    }

    #[test]
    fn reactor_reports_socket_readability() {
        use std::io::Write as _;
        let reactor = Reactor::new().unwrap();
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        reactor.register(b.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut events = Vec::new();
        reactor.poll(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty(), "nothing written yet");
        a.write_all(b"x").unwrap();
        reactor.poll(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        // Write interest on a fresh socket reports writable immediately.
        reactor.modify(b.as_raw_fd(), 7, Interest::BOTH).unwrap();
        reactor.poll(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.writable));
        reactor.deregister(b.as_raw_fd()).unwrap();
    }
}
