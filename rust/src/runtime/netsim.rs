//! Network conditioner reproducing the paper's Table-II links on localhost
//! TCP.  Two effects are modelled independently:
//!
//! * **serialization delay** — the sender's wall-clock cost of pushing
//!   `bytes` through a link of the configured *measured throughput*
//!   (token-bucket pacing: a shared per-link clock advances by
//!   bytes/throughput per message, so concurrent TX FIFOs share the pipe
//!   exactly like sockets sharing one physical link);
//! * **propagation latency** — each message carries its send timestamp and
//!   the receiver defers delivery until `ts + latency`, which delays
//!   pipeline fill but not steady-state throughput (as on a real link).

use crate::util::json::Json;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone)]
pub struct LinkModel {
    pub name: String,
    /// Measured throughput in bytes/second (Table II "measured").
    pub throughput_bps: f64,
    /// One-way latency in milliseconds (Table II "latency").
    pub latency_ms: f64,
}

impl LinkModel {
    pub fn new(name: &str, throughput_mbytes_s: f64, latency_ms: f64) -> Self {
        LinkModel {
            name: name.to_string(),
            throughput_bps: throughput_mbytes_s * 1e6,
            latency_ms,
        }
    }

    /// Time-scaled copy: when experiments run with DeviceModel.time_scale
    /// = k (sim targets inflated k-fold so real XLA compute fits under
    /// them), the link must slow down by the same factor to keep the
    /// compute/communication ratio faithful; reported numbers are divided
    /// by k afterwards.
    pub fn scaled(&self, time_scale: f64) -> Self {
        if self.is_ideal() || time_scale == 1.0 {
            return self.clone();
        }
        LinkModel {
            name: self.name.clone(),
            throughput_bps: self.throughput_bps / time_scale,
            latency_ms: self.latency_ms * time_scale,
        }
    }

    /// An unshaped link (localhost native speed).
    pub fn ideal() -> Self {
        LinkModel { name: "ideal".into(), throughput_bps: 0.0, latency_ms: 0.0 }
    }

    pub fn is_ideal(&self) -> bool {
        self.throughput_bps <= 0.0 && self.latency_ms <= 0.0
    }

    /// Pure-model transmission time for a message (used by analytic
    /// benches and tests): serialization + latency.
    pub fn tx_time_ms(&self, bytes: usize) -> f64 {
        let ser = if self.throughput_bps > 0.0 {
            bytes as f64 / self.throughput_bps * 1e3
        } else {
            0.0
        };
        ser + self.latency_ms
    }

    pub fn from_json(name: &str, v: &Json) -> anyhow::Result<Self> {
        Ok(LinkModel {
            name: name.to_string(),
            throughput_bps: v.get("throughput_mbytes_s")?.num()? * 1e6,
            latency_ms: v.get("latency_ms")?.num()?,
        })
    }
}

/// Sender-side pacer: shared by all TX FIFOs mapped onto one link.
#[derive(Debug, Clone)]
pub struct LinkShaper {
    model: LinkModel,
    /// Virtual time (Instant) until which the link is busy.
    busy_until: Arc<Mutex<Option<Instant>>>,
}

impl LinkShaper {
    pub fn new(model: LinkModel) -> Self {
        LinkShaper { model, busy_until: Arc::new(Mutex::new(None)) }
    }

    pub fn model(&self) -> &LinkModel {
        &self.model
    }

    /// Block the sender for this message's serialization slot and return
    /// the timestamp (ns since epoch) to stamp into the frame header.
    pub fn send_slot(&self, bytes: usize) -> u64 {
        if self.model.throughput_bps > 0.0 {
            let ser = Duration::from_secs_f64(bytes as f64 / self.model.throughput_bps);
            let wake = {
                let mut busy = self.busy_until.lock().unwrap();
                let now = Instant::now();
                let start = busy.map(|b| b.max(now)).unwrap_or(now);
                let end = start + ser;
                *busy = Some(end);
                end
            };
            let now = Instant::now();
            if wake > now {
                std::thread::sleep(wake - now);
            }
        }
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_nanos() as u64
    }

    /// Receiver-side: wait until `send_ts + latency` has passed.
    pub fn delivery_wait(&self, send_ts_ns: u64) {
        if self.model.latency_ms <= 0.0 {
            return;
        }
        let deliver_at = send_ts_ns + (self.model.latency_ms * 1e6) as u64;
        let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_nanos() as u64;
        if deliver_at > now {
            std::thread::sleep(Duration::from_nanos(deliver_at - now));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx_time_model_matches_table2() {
        // N2-i7 Ethernet: 11.2 MB/s, 1.49 ms. Raw vehicle frame = 110592 B.
        let link = LinkModel::new("n2_i7_eth", 11.2, 1.49);
        let t = link.tx_time_ms(110592);
        assert!((t - (110592.0 / 11.2e6 * 1e3 + 1.49)).abs() < 1e-9);
        assert!(t > 9.8 && t < 12.0);
    }

    #[test]
    fn ideal_link_is_free() {
        let link = LinkModel::ideal();
        assert!(link.is_ideal());
        assert_eq!(link.tx_time_ms(1 << 20), 0.0);
    }

    #[test]
    fn shaper_paces_to_throughput() {
        // 10 MB/s; 5 messages of 100 KB = 500 KB -> >= 50 ms.
        let shaper = LinkShaper::new(LinkModel::new("t", 10.0, 0.0));
        let t0 = Instant::now();
        for _ in 0..5 {
            shaper.send_slot(100_000);
        }
        let el = t0.elapsed().as_secs_f64() * 1e3;
        assert!(el >= 45.0, "elapsed {el} ms");
        assert!(el < 120.0, "elapsed {el} ms");
    }

    #[test]
    fn shaper_shares_pipe_between_threads() {
        let shaper = LinkShaper::new(LinkModel::new("t", 10.0, 0.0));
        let t0 = Instant::now();
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let s = shaper.clone();
                std::thread::spawn(move || {
                    for _ in 0..3 {
                        s.send_slot(100_000);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // 6 x 100 KB at 10 MB/s = 60 ms even with 2 concurrent senders.
        let el = t0.elapsed().as_secs_f64() * 1e3;
        assert!(el >= 55.0, "elapsed {el} ms");
    }

    #[test]
    fn observed_throughput_never_exceeds_model() {
        // 5 MB/s model; 10 x 50 KB = 500 KB must take >= 100 ms, i.e. the
        // observed rate stays at or below the configured rate (+ jitter).
        let shaper = LinkShaper::new(LinkModel::new("t", 5.0, 0.0));
        let bytes_total = 10 * 50_000;
        let t0 = Instant::now();
        for _ in 0..10 {
            shaper.send_slot(50_000);
        }
        let secs = t0.elapsed().as_secs_f64();
        let observed_mb_s = bytes_total as f64 / 1e6 / secs;
        assert!(observed_mb_s <= 5.5, "observed {observed_mb_s} MB/s over a 5 MB/s link");
        assert!(secs >= 0.095, "500 KB at 5 MB/s finished in {secs} s");
    }

    #[test]
    fn latency_injection_bounds_observed_delay() {
        // One-way latency of 25 ms: a message stamped at send time is not
        // deliverable earlier than ts + 25 ms, and is released promptly
        // after (within scheduler slack).
        let shaper = LinkShaper::new(LinkModel::new("t", 0.0, 25.0));
        let ts = shaper.send_slot(1024);
        let t0 = Instant::now();
        shaper.delivery_wait(ts);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(23), "waited only {waited:?}");
        // A stale timestamp (already past its delivery time) must not
        // wait another latency period — latency injects delay, it never
        // accumulates.  Five stale waits with latency wrongly re-applied
        // would take >= 125 ms; the bound is generous for CI scheduler
        // stalls while still catching that.
        let t1 = Instant::now();
        for _ in 0..5 {
            shaper.delivery_wait(ts);
        }
        assert!(t1.elapsed() < Duration::from_millis(100), "stale waits took {:?}", t1.elapsed());
    }

    #[test]
    fn delivery_wait_enforces_latency() {
        let shaper = LinkShaper::new(LinkModel::new("t", 0.0, 20.0));
        let ts = SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_nanos() as u64;
        let t0 = Instant::now();
        shaper.delivery_wait(ts);
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn from_json_parses() {
        let j = Json::parse(r#"{"throughput_mbytes_s": 2.3, "latency_ms": 2.15}"#).unwrap();
        let l = LinkModel::from_json("n2_i7_wifi", &j).unwrap();
        assert!((l.throughput_bps - 2.3e6).abs() < 1.0);
        assert!((l.latency_ms - 2.15).abs() < 1e-9);
    }
}
