//! Tensor byte-buffer helpers: the runtime moves tokens as raw little-endian
//! f32 buffers (exactly what the AOT weight `.bin` files contain and what
//! the PJRT literals are built from).

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Load a raw little-endian f32 tensor file emitted by `aot.py`.
pub fn load_f32_bin(path: &Path, expected_elems: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expected_elems * 4 {
        bail!(
            "{}: expected {} f32 elems ({} bytes), file has {} bytes",
            path.display(),
            expected_elems,
            expected_elems * 4,
            bytes.len()
        );
    }
    Ok(bytes_to_f32(&bytes))
}

pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0);
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Borrow a little-endian f32 byte buffer as `&[f32]` without copying.
/// `None` when the borrow would be unsound or wrong: length not a
/// multiple of 4, pointer not 4-byte aligned (heap `Vec<u8>` alignment
/// is not guaranteed), or a big-endian target (the bytes are LE on the
/// wire, so a cast would mis-read them).  Callers fall back to
/// [`bytes_to_f32`] — same values, one copy.
pub fn cast_f32_slice(bytes: &[u8]) -> Option<&[f32]> {
    if cfg!(target_endian = "big") {
        return None;
    }
    if bytes.len() % 4 != 0 {
        return None;
    }
    let ptr = bytes.as_ptr();
    if (ptr as usize) % std::mem::align_of::<f32>() != 0 {
        return None;
    }
    // SAFETY: length and alignment checked above; f32 has no invalid
    // bit patterns; the borrow inherits `bytes`' lifetime, and u8 -> f32
    // reinterpretation on a little-endian target matches the buffer's
    // declared LE layout.
    Some(unsafe { std::slice::from_raw_parts(ptr as *const f32, bytes.len() / 4) })
}

pub fn f32_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    f32_extend_bytes(vals, &mut out);
    out
}

/// Serialize into a caller-owned buffer (cleared first): the
/// allocation-free counterpart of [`f32_to_bytes`] for hot loops that
/// reuse one output `Vec` across frames.
pub fn f32_extend_bytes(vals: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Number of elements implied by a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32_bytes() {
        let vals = vec![0.0f32, -1.5, 3.25, f32::MAX, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&vals)), vals);
    }

    #[test]
    fn f32_extend_bytes_clears_and_reuses() {
        let mut out = f32_to_bytes(&[9.0; 10]); // stale content + capacity
        let base_cap = out.capacity();
        f32_extend_bytes(&[1.0, -2.0], &mut out);
        assert_eq!(out, f32_to_bytes(&[1.0, -2.0]));
        assert_eq!(out.capacity(), base_cap, "reused, not reallocated");
    }

    #[test]
    fn cast_f32_slice_borrows_aligned_buffers() {
        let vals = vec![1.0f32, -2.5, 0.25];
        let bytes = f32_to_bytes(&vals);
        if let Some(s) = cast_f32_slice(&bytes) {
            assert_eq!(s, &vals[..], "borrowed view reads the same values");
            assert_eq!(s.as_ptr() as usize, bytes.as_ptr() as usize, "no copy");
        }
        // Ragged length never borrows.
        assert!(cast_f32_slice(&bytes[..5]).is_none());
        // A deliberately misaligned view falls back (offset by 1 byte
        // from a 4-aligned base is never 4-aligned).
        if bytes.as_ptr() as usize % 4 == 0 {
            assert!(cast_f32_slice(&bytes[1..9]).is_none());
        }
        // Fallback agrees with the decoding path bit-for-bit.
        assert_eq!(bytes_to_f32(&bytes), vals);
    }

    #[test]
    fn numel_products() {
        assert_eq!(numel(&[96, 96, 3]), 27648);
        assert_eq!(numel(&[]), 1);
    }

    #[test]
    fn load_f32_bin_checks_size() {
        let dir = std::env::temp_dir().join("ep_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        std::fs::write(&p, f32_to_bytes(&[1.0, 2.0])).unwrap();
        assert_eq!(load_f32_bin(&p, 2).unwrap(), vec![1.0, 2.0]);
        assert!(load_f32_bin(&p, 3).is_err());
    }
}
