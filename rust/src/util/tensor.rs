//! Tensor byte-buffer helpers: the runtime moves tokens as raw little-endian
//! f32 buffers (exactly what the AOT weight `.bin` files contain and what
//! the PJRT literals are built from).

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Load a raw little-endian f32 tensor file emitted by `aot.py`.
pub fn load_f32_bin(path: &Path, expected_elems: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expected_elems * 4 {
        bail!(
            "{}: expected {} f32 elems ({} bytes), file has {} bytes",
            path.display(),
            expected_elems,
            expected_elems * 4,
            bytes.len()
        );
    }
    Ok(bytes_to_f32(&bytes))
}

pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0);
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn f32_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Number of elements implied by a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32_bytes() {
        let vals = vec![0.0f32, -1.5, 3.25, f32::MAX, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&vals)), vals);
    }

    #[test]
    fn numel_products() {
        assert_eq!(numel(&[96, 96, 3]), 27648);
        assert_eq!(numel(&[]), 1);
    }

    #[test]
    fn load_f32_bin_checks_size() {
        let dir = std::env::temp_dir().join("ep_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        std::fs::write(&p, f32_to_bytes(&[1.0, 2.0])).unwrap();
        assert_eq!(load_f32_bin(&p, 2).unwrap(), vec![1.0, 2.0]);
        assert!(load_f32_bin(&p, 3).is_err());
    }
}
