//! Minimal JSON parser / serializer.
//!
//! The build environment vendors no serde, so Edge-PRUNE carries its own
//! JSON substrate: enough of RFC 8259 to read the artifact manifest and the
//! platform / mapping / deployment-plan files, plus a writer for the plans
//! and metric reports the tools emit.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (the manifest's integers are all
/// < 2^53 so this is lossless for our use).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    Type { expected: &'static str, path: String },
    Missing(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse(at, msg) => write!(f, "json parse error at byte {at}: {msg}"),
            JsonError::Type { expected, path } => {
                write!(f, "json type error: expected {expected} at {path}")
            }
            JsonError::Missing(key) => write!(f, "json missing key {key:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing garbage".into()));
        }
        Ok(v)
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::Type { expected: "object", path: String::new() }),
        }
    }

    pub fn arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(JsonError::Type { expected: "array", path: String::new() }),
        }
    }

    pub fn str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type { expected: "string", path: String::new() }),
        }
    }

    pub fn num(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Type { expected: "number", path: String::new() }),
        }
    }

    pub fn int(&self) -> Result<i64, JsonError> {
        Ok(self.num()? as i64)
    }

    pub fn usize(&self) -> Result<usize, JsonError> {
        Ok(self.num()? as usize)
    }

    pub fn bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type { expected: "bool", path: String::new() }),
        }
    }

    /// `obj["key"]` with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.obj()?.get(key).ok_or_else(|| JsonError::Missing(key.into()))
    }

    /// Optional key lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.i, msg.to_string()))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected {s}"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected value"),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| JsonError::Parse(self.i, "bad utf8".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.i, "bad hex".into()))?;
                            // Surrogate pairs: accept but map lone surrogates
                            // to U+FFFD (manifest never contains them).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Perf: bulk-copy the run up to the next quote or
                    // escape (validating UTF-8 once per run, not per char).
                    let start = self.i;
                    let mut end = start;
                    while end < self.b.len() && self.b[end] != b'"' && self.b[end] != b'\\' {
                        end += 1;
                    }
                    let run = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| JsonError::Parse(start, "bad utf8".into()))?;
                    s.push_str(run);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, e.to_string()))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.str().unwrap(), "é");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n":7,"s":"x","b":true,"a":[]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().int().unwrap(), 7);
        assert_eq!(v.get("n").unwrap().usize().unwrap(), 7);
        assert!(v.get("s").unwrap().num().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.opt("missing").is_none());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(294912.0).to_string(), "294912");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }
}
