//! Deterministic PRNG (splitmix64 + xoshiro256**).
//!
//! Used for synthetic frame generation, the property-test harness, and
//! workload jitter. No external crates; seeded everywhere for reproducible
//! experiments.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a byte buffer with an f32 pattern (synthetic frame pixels).
    pub fn fill_f32(&mut self, out: &mut [u8], lo: f32, hi: f32) {
        assert_eq!(out.len() % 4, 0);
        for chunk in out.chunks_exact_mut(4) {
            chunk.copy_from_slice(&self.f32_range(lo, hi).to_le_bytes());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = r.range(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn fill_f32_within_range() {
        let mut r = Rng::new(5);
        let mut buf = vec![0u8; 64];
        r.fill_f32(&mut buf, -1.0, 1.0);
        for c in buf.chunks_exact(4) {
            let v = f32::from_le_bytes(c.try_into().unwrap());
            assert!((-1.0..1.0).contains(&v));
        }
    }
}
