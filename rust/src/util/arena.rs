//! Bump-allocated f32 scratch arena for per-plan kernel workspaces.
//!
//! A kernel (an `EngineShard`, a conv actor) allocates its scratch
//! regions once at bind time and reuses them every firing: `alloc`
//! bumps a cursor inside one backing `Vec<f32>` and returns a small
//! copyable handle; the backing storage grows only while handles are
//! being allocated (warmup), after which the steady state touches the
//! heap zero times.  Handles index the arena instead of borrowing it so
//! a kernel can hold several scratch regions and borrow them mutably
//! together ([`Arena::pair_mut`] / [`Arena::tri_mut`]) without fighting
//! the borrow checker.

/// Handle to one region of an [`Arena`] (offset + length, `Copy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaBuf {
    off: usize,
    len: usize,
}

impl ArenaBuf {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Bump allocator over one `Vec<f32>`.
#[derive(Debug, Default)]
pub struct Arena {
    buf: Vec<f32>,
    used: usize,
}

impl Arena {
    pub fn new() -> Self {
        Arena::default()
    }

    /// Preallocate the backing store so subsequent `alloc` calls never
    /// touch the heap.
    pub fn with_capacity(floats: usize) -> Self {
        Arena { buf: vec![0.0; floats], used: 0 }
    }

    /// Reserve `len` zero-initialized floats, growing the backing store
    /// if (and only if) the preallocated capacity is exhausted.
    pub fn alloc(&mut self, len: usize) -> ArenaBuf {
        let off = self.used;
        self.used += len;
        if self.used > self.buf.len() {
            self.buf.resize(self.used, 0.0);
        }
        ArenaBuf { off, len }
    }

    /// Floats handed out so far.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Forget every handle (callers must re-`alloc`; old handles would
    /// alias new ones).
    pub fn reset(&mut self) {
        self.used = 0;
    }

    pub fn get(&self, b: ArenaBuf) -> &[f32] {
        &self.buf[b.off..b.off + b.len]
    }

    pub fn get_mut(&mut self, b: ArenaBuf) -> &mut [f32] {
        &mut self.buf[b.off..b.off + b.len]
    }

    /// Two disjoint regions borrowed mutably at once.  `a` must lie
    /// entirely before `b` (allocation order).
    pub fn pair_mut(&mut self, a: ArenaBuf, b: ArenaBuf) -> (&mut [f32], &mut [f32]) {
        assert!(a.off + a.len <= b.off, "regions must be disjoint and ordered");
        let (left, right) = self.buf.split_at_mut(b.off);
        (&mut left[a.off..a.off + a.len], &mut right[..b.len])
    }

    /// Three disjoint regions borrowed mutably at once, in allocation
    /// order.
    pub fn tri_mut(
        &mut self,
        a: ArenaBuf,
        b: ArenaBuf,
        c: ArenaBuf,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        assert!(a.off + a.len <= b.off, "a/b must be disjoint and ordered");
        assert!(b.off + b.len <= c.off, "b/c must be disjoint and ordered");
        let (left, rest) = self.buf.split_at_mut(b.off);
        let (mid, right) = rest.split_at_mut(c.off - b.off);
        (
            &mut left[a.off..a.off + a.len],
            &mut mid[..b.len],
            &mut right[..c.len],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_within_capacity_never_grows() {
        let mut a = Arena::with_capacity(16);
        let probe = a.alloc(0);
        let base = a.get(probe).as_ptr() as usize;
        let x = a.alloc(8);
        let y = a.alloc(8);
        assert_eq!(a.used(), 16);
        a.get_mut(x).fill(1.0);
        a.get_mut(y).fill(2.0);
        assert_eq!(a.get(x)[0], 1.0);
        assert_eq!(a.get(y)[7], 2.0);
        // Backing store never moved: same base pointer.
        assert_eq!(a.get(x).as_ptr() as usize, base);
    }

    #[test]
    fn alloc_beyond_capacity_grows_zeroed() {
        let mut a = Arena::with_capacity(4);
        let big = a.alloc(10);
        assert_eq!(a.get(big), &[0.0; 10][..]);
    }

    #[test]
    fn pair_and_tri_borrows_are_disjoint() {
        let mut a = Arena::with_capacity(12);
        let (x, y, z) = (a.alloc(4), a.alloc(3), a.alloc(5));
        {
            let (xs, ys, zs) = a.tri_mut(x, y, z);
            xs.fill(1.0);
            ys.fill(2.0);
            zs.fill(3.0);
            assert_eq!((xs.len(), ys.len(), zs.len()), (4, 3, 5));
        }
        let (xs, zs) = a.pair_mut(x, z);
        assert_eq!(xs[3], 1.0);
        assert_eq!(zs[0], 3.0);
        assert_eq!(a.get(y), &[2.0; 3][..]);
    }

    #[test]
    fn reset_reuses_storage() {
        let mut a = Arena::with_capacity(8);
        let x = a.alloc(8);
        a.get_mut(x).fill(9.0);
        a.reset();
        assert_eq!(a.used(), 0);
        let y = a.alloc(8);
        assert_eq!(y.len(), 8);
        // Same storage, stale values visible until overwritten.
        assert_eq!(a.get(y)[0], 9.0);
    }

    #[test]
    #[should_panic(expected = "disjoint")]
    fn unordered_pair_panics() {
        let mut a = Arena::with_capacity(8);
        let (x, y) = (a.alloc(4), a.alloc(4));
        let _ = a.pair_mut(y, x);
    }
}
