//! Tiny CLI argument parser (no clap in the vendored set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and a usage printer.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit arg list (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional.
                    args.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(body.to_string(), v);
                } else {
                    args.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn parse() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.str_opt(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error if unknown flags are present (catches typos in scripts).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn key_value_styles() {
        // NOTE: `--flag value`-style always binds the following non-flag
        // token as the value, so boolean flags must come last or use
        // `--flag=true`.
        let a = parse(&["--x", "1", "--y=2", "pos", "--flag"]);
        assert_eq!(a.str_opt("x"), Some("1"));
        assert_eq!(a.str_opt("y"), Some("2"));
        assert!(a.bool_flag("flag"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n", "42", "--r", "1.5"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
        assert_eq!(a.f64_or("r", 0.0).unwrap(), 1.5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(a.f64_or("n", 0.0).is_ok());
        let bad = parse(&["--n", "xyz"]);
        assert!(bad.usize_or("n", 0).is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn require_and_check_known() {
        let a = parse(&["--model", "vehicle"]);
        assert_eq!(a.require("model").unwrap(), "vehicle");
        assert!(a.require("missing").is_err());
        assert!(a.check_known(&["model"]).is_ok());
        assert!(a.check_known(&["other"]).is_err());
    }
}
