//! Minimal property-based testing harness (proptest is not vendored).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it performs a simple halving shrink over
//! the generator's size parameter and reports the seed so the case can be
//! replayed deterministically.

use super::rng::Rng;

/// Run a property over `cases` generated inputs. `gen` receives an Rng and a
/// size hint in [1, max_size]; `prop` returns Err(reason) on violation.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    max_size: usize,
    gen: impl Fn(&mut Rng, usize) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E37_79B9).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let size = 1 + (case * max_size) / cases.max(1); // ramp sizes up
        let input = gen(&mut rng, size.max(1));
        if let Err(msg) = prop(&input) {
            // Shrink: retry with halved sizes from the same seed.
            let mut shrink_size = size;
            let mut smallest: (T, String) = (input, msg);
            while shrink_size > 1 {
                shrink_size /= 2;
                let mut r2 = Rng::new(case_seed);
                let cand = gen(&mut r2, shrink_size);
                if let Err(m) = prop(&cand) {
                    smallest = (cand, m);
                } else {
                    break;
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}, case_seed={case_seed}):\n  input: {:?}\n  reason: {}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            1,
            50,
            100,
            |rng, size| rng.below(size.max(1)),
            |&x| if x < 100 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            2,
            50,
            100,
            |rng, size| rng.below(size.max(1)),
            |&x| if x < 3 { Ok(()) } else { Err(format!("{x} >= 3")) },
        );
    }

    #[test]
    fn generator_sizes_ramp() {
        let mut max_seen = 0;
        forall(
            3,
            20,
            64,
            |_, size| size,
            |&s| {
                if s > 0 && s <= 64 {
                    Ok(())
                } else {
                    Err("size out of range".into())
                }
            },
        );
        let _ = &mut max_seen;
    }
}
