//! Substrate utilities built from scratch for the offline environment:
//! JSON, PRNG, tensor byte I/O, CLI parsing, a bump-allocated scratch
//! arena, and a property-test harness.

pub mod arena;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tensor;
