//! Artifact manifest: the contract between `python/compile/aot.py` (build
//! time) and the Rust runtime.  Describes each model's dataflow graph
//! (actors, edges, token sizes — cross-checked against the paper's Fig 2 /
//! Fig 3 counts in tests) and each HLO-compiled actor's artifact paths,
//! shapes, weights, and FLOPs estimate.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct WeightMeta {
    pub file: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct HloEntry {
    pub name: String,
    pub hlo: String,
    pub hlo_pallas: Option<String>,
    pub in_shapes: Vec<Vec<usize>>,
    pub out_shape: Vec<usize>,
    pub out_bytes: usize,
    pub flops: u64,
    pub weights: Vec<WeightMeta>,
}

#[derive(Debug, Clone)]
pub struct EdgeMeta {
    pub src: String,
    pub dst: String,
    pub bytes: usize,
}

#[derive(Debug, Clone)]
pub struct TapMeta {
    pub actor: String,
    pub anchors: usize,
    pub h: usize,
    pub w: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub num_anchors: usize,
    pub actors: Vec<String>,
    pub edges: Vec<EdgeMeta>,
    pub taps: Vec<TapMeta>,
    pub hlo_entries: BTreeMap<String, HloEntry>,
    /// Order of hlo entries as emitted (== precedence order).
    pub hlo_order: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
}

fn usizes(j: &Json) -> Result<Vec<usize>> {
    j.arr()?.iter().map(|x| Ok(x.usize()?)).collect()
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = BTreeMap::new();
        for (name, m) in v.get("models")?.obj()? {
            models.insert(name.clone(), ModelMeta::from_json(name, m)?);
        }
        Ok(Manifest { root: artifacts_dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| anyhow!("model {name} not in manifest"))
    }

    /// Default artifacts directory: $EDGE_PRUNE_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("EDGE_PRUNE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

impl ModelMeta {
    fn from_json(name: &str, m: &Json) -> Result<ModelMeta> {
        let actors = m
            .get("actors")?
            .arr()?
            .iter()
            .map(|a| Ok(a.str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let edges = m
            .get("edges")?
            .arr()?
            .iter()
            .map(|e| {
                Ok(EdgeMeta {
                    src: e.get("src")?.str()?.to_string(),
                    dst: e.get("dst")?.str()?.to_string(),
                    bytes: e.get("bytes")?.usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let taps = match m.opt("taps") {
            None => Vec::new(),
            Some(t) => t
                .arr()?
                .iter()
                .map(|x| {
                    Ok(TapMeta {
                        actor: x.get("actor")?.str()?.to_string(),
                        anchors: x.get("anchors")?.usize()?,
                        h: x.get("h")?.usize()?,
                        w: x.get("w")?.usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        };
        let mut hlo_entries = BTreeMap::new();
        let mut hlo_order = Vec::new();
        for e in m.get("hlo_entries")?.arr()? {
            let name = e.get("name")?.str()?.to_string();
            let weights = e
                .get("weights")?
                .arr()?
                .iter()
                .map(|w| {
                    Ok(WeightMeta {
                        file: w.get("file")?.str()?.to_string(),
                        shape: usizes(w.get("shape")?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let in_shapes = e
                .get("inputs")?
                .arr()?
                .iter()
                .map(|i| usizes(i.get("shape")?))
                .collect::<Result<Vec<_>>>()?;
            hlo_order.push(name.clone());
            hlo_entries.insert(
                name.clone(),
                HloEntry {
                    name,
                    hlo: e.get("hlo")?.str()?.to_string(),
                    hlo_pallas: e.opt("hlo_pallas").map(|p| p.str().map(String::from)).transpose()?,
                    in_shapes,
                    out_shape: usizes(e.get("out_shape")?)?,
                    out_bytes: e.get("out_bytes")?.usize()?,
                    flops: e.get("flops")?.int()? as u64,
                    weights,
                },
            );
        }
        Ok(ModelMeta {
            name: name.to_string(),
            input_shape: usizes(m.get("input_shape")?)?,
            num_classes: m.get("num_classes")?.usize()?,
            num_anchors: m.opt("num_anchors").map(|j| j.usize()).transpose()?.unwrap_or(0),
            actors,
            edges,
            taps,
            hlo_entries,
            hlo_order,
        })
    }

    /// Bytes of one input frame token.
    pub fn input_bytes(&self) -> usize {
        self.input_shape.iter().product::<usize>() * 4
    }

    /// Per-actor FLOPs map (cost-model input).
    pub fn flops_map(&self) -> BTreeMap<String, u64> {
        self.hlo_entries.iter().map(|(k, v)| (k.clone(), v.flops)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
          "models": {
            "toy": {
              "input_shape": [4, 4, 1],
              "num_classes": 2,
              "actors": ["input", "l1", "sink"],
              "edges": [
                {"src": "input", "dst": "l1", "bytes": 64},
                {"src": "l1", "dst": "sink", "bytes": 8}
              ],
              "hlo_entries": [
                {"name": "l1", "hlo": "toy/l1.hlo.txt",
                 "inputs": [{"shape": [4,4,1], "dtype": "f32"}],
                 "out_shape": [2], "out_bytes": 8, "flops": 100,
                 "weights": [{"file": "weights/toy.l1.w.bin", "shape": [16,2]}]}
              ]
            }
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_model_meta() {
        let j = sample();
        let m = ModelMeta::from_json("toy", j.get("models").unwrap().get("toy").unwrap()).unwrap();
        assert_eq!(m.actors.len(), 3);
        assert_eq!(m.edges[0].bytes, 64);
        assert_eq!(m.input_bytes(), 64);
        let e = &m.hlo_entries["l1"];
        assert_eq!(e.flops, 100);
        assert_eq!(e.weights[0].shape, vec![16, 2]);
        assert!(e.hlo_pallas.is_none());
        assert_eq!(m.flops_map()["l1"], 100);
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        let v = m.model("vehicle").unwrap();
        assert_eq!(v.actors, vec!["input", "l1", "l2", "l3", "l45", "sink"]);
        assert_eq!(v.edges.iter().find(|e| e.src == "l1").unwrap().bytes, 294912);
        if let Ok(s) = m.model("ssd") {
            assert_eq!(s.actors.len(), 53);
            assert_eq!(s.edges.len(), 69);
            assert_eq!(s.num_anchors, 1917);
        }
    }
}
