//! Model graph builders + artifact manifest (vehicle CNN, SSD-Mobilenet).

pub mod builder;
pub mod manifest;
pub mod vehicle;
