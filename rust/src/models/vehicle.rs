//! Vehicle-classification model helpers: the dual-input variant of
//! paper §IV.C — "actors Input through L3 were replicated into two
//! instances each, joining at a two-input L4L5 actor".
//!
//! Instance actors use the `#2` suffix; the kernel factory maps them to
//! the same HLO entry (`l1#2` runs the `l1` executable), and the join is
//! the `l45_dual` executable lowered by aot.py with two (100,) inputs.

use crate::models::manifest::{EdgeMeta, ModelMeta};
use anyhow::{anyhow, Result};

/// Derive the dual-input graph metadata from the single-input vehicle
/// metadata.  Actor order: branch 1, branch 2, join, sink (precedence).
pub fn dual_meta(vehicle: &ModelMeta) -> Result<ModelMeta> {
    if !vehicle.hlo_entries.contains_key("l45_dual") {
        return Err(anyhow!("manifest lacks l45_dual (re-run `make artifacts`)"));
    }
    let mut m = vehicle.clone();
    m.name = "vehicle_dual".to_string();
    m.actors = vec![
        "input".into(),
        "l1".into(),
        "l2".into(),
        "l3".into(),
        "input#2".into(),
        "l1#2".into(),
        "l2#2".into(),
        "l3#2".into(),
        "l45_dual".into(),
        "sink".into(),
    ];
    let byte = |src: &str| -> usize {
        vehicle
            .edges
            .iter()
            .find(|e| e.src == src)
            .map(|e| e.bytes)
            .unwrap_or(0)
    };
    let e = |src: &str, dst: &str, bytes: usize| EdgeMeta {
        src: src.to_string(),
        dst: dst.to_string(),
        bytes,
    };
    m.edges = vec![
        e("input", "l1", byte("input")),
        e("l1", "l2", byte("l1")),
        e("l2", "l3", byte("l2")),
        e("l3", "l45_dual", byte("l3")),
        e("input#2", "l1#2", byte("input")),
        e("l1#2", "l2#2", byte("l1")),
        e("l2#2", "l3#2", byte("l2")),
        e("l3#2", "l45_dual", byte("l3")),
        e("l45_dual", "sink", byte("l45")),
    ];
    Ok(m)
}

/// The paper's §IV.C mapping: 1st instance on the N2, the 2nd instance's
/// Input on the N270, everything else on the i7 edge server.
pub fn dual_mapping() -> crate::platform::Mapping {
    let mut map = crate::platform::Mapping::new();
    for a in ["input", "l1", "l2", "l3"] {
        map.assign(a, "n2");
    }
    map.assign("input#2", "n270");
    for a in ["l1#2", "l2#2", "l3#2", "l45_dual", "sink"] {
        map.assign(a, "i7");
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::build_graph;
    use crate::models::manifest::Manifest;

    fn vehicle() -> Option<ModelMeta> {
        let dir = Manifest::default_dir();
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap().model("vehicle").unwrap().clone())
    }

    #[test]
    fn dual_meta_builds_valid_graph() {
        let Some(v) = vehicle() else { return };
        let dual = dual_meta(&v).unwrap();
        assert_eq!(dual.actors.len(), 10);
        assert_eq!(dual.edges.len(), 9);
        let g = build_graph(&dual, 4).unwrap();
        assert!(g.topo_order().is_ok());
        // The join actor has exactly two in-ports.
        let join = g.actor_by_name("l45_dual").unwrap();
        assert_eq!(g.in_edges(join).len(), 2);
        let report = crate::analyzer::analyze(&g).unwrap();
        assert!(report.schedulable);
    }

    #[test]
    fn dual_mapping_covers_all_actors() {
        let Some(v) = vehicle() else { return };
        let dual = dual_meta(&v).unwrap();
        let map = dual_mapping();
        for a in &dual.actors {
            assert!(map.assignments.contains_key(a), "{a} unmapped");
        }
        assert_eq!(map.device_of("input#2").unwrap(), "n270");
        assert_eq!(map.device_of("l45_dual").unwrap(), "i7");
    }
}
