//! Graph builder + kernel factory: turns the manifest's graph metadata
//! into a live `AppGraph` and binds each actor to its kernel (real CPU
//! compute or XLA executable, vision post-processing, source/sink, or
//! TX/RX endpoint).
//!
//! Actor-name conventions:
//! * `input` -> synthetic SourceKernel, `sink` -> SinkKernel
//! * names in `hlo_entries` -> a real-compute `DnnLayerKernel` when the
//!   manifest shapes classify as Conv/DwConv/Dense AND the layer's
//!   weight artifact is absent (synthetic name-seeded parameters; the
//!   no-PJRT default), otherwise the `XlaKernel` executable — compiled
//!   HLO stays ground truth for its own weights.  Instance suffixes
//!   `#2` map to the same entry: the dual-input use case replicates
//!   actors.
//! * `prior<i>` / `locr<i>` / `concat_loc` / `concat_conf_softmax` /
//!   `box_decode` / `nms` / `tracker` -> vision kernels
//! * `__tx<i>` / `__rx<i>` -> socket FIFO endpoints (bound by the
//!   distributed launcher, not here).

use crate::dataflow::{AppGraph, TokenPool};
use crate::models::manifest::{HloEntry, ModelMeta};
use crate::runtime::kernels::*;
use crate::runtime::wire::{Precision, WireDtype};
use crate::runtime::xla_exec::{XlaKernel, XlaService};
use crate::util::tensor;
use crate::vision::kernels::*;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

pub const DEFAULT_CAPACITY: usize = 4;

/// Build the application graph from manifest metadata (actors in file
/// order; edges in file order so port indices match the kernel contracts).
pub fn build_graph(meta: &ModelMeta, capacity: usize) -> Result<AppGraph> {
    let mut g = AppGraph::new();
    let mut ids = BTreeMap::new();
    for name in &meta.actors {
        ids.insert(name.clone(), g.add_spa(name));
    }
    for e in &meta.edges {
        let s = *ids.get(&e.src).ok_or_else(|| anyhow!("edge src {} unknown", e.src))?;
        let d = *ids.get(&e.dst).ok_or_else(|| anyhow!("edge dst {} unknown", e.dst))?;
        g.connect(s, d, e.bytes, capacity);
    }
    g.validate().map_err(|e| anyhow!("{e}"))?;
    Ok(g)
}

/// Strip an instance suffix: "l1#2" -> "l1".
pub fn base_name(actor: &str) -> &str {
    actor.split('#').next().unwrap()
}

/// Options for kernel construction.
#[derive(Clone)]
pub struct KernelOptions {
    pub frames: u64,
    pub seed: u64,
    pub keep_last: bool,
    /// Execute DNN actors as real CPU kernels (`DnnLayerKernel`) when
    /// the manifest shapes classify; `false` forces the XLA executable
    /// for every `hlo_entries` actor.
    pub real_compute: bool,
    /// Row-split worker count inside each real compute kernel (1 =
    /// single-threaded firing; the engine already parallelizes across
    /// actors).
    pub threads: usize,
    /// Shared token buffer pool: real kernels draw output payloads from
    /// it and the engine recycles consumed tokens into it.
    pub pool: TokenPool,
    /// Compute precision of the real DNN kernels (`--precision`): f32
    /// reference kernels or the int8 GEMM/matvec path.
    pub precision: Precision,
    /// Activation wire dtype of the TX/RX FIFOs (`--wire`): tokens
    /// crossing a cut edge transmit as int8/fp16 instead of raw f32.
    /// Both workers of a deployment must agree (it is a launch-time
    /// contract here; the serving protocol negotiates it per session).
    pub wire: WireDtype,
}

impl Default for KernelOptions {
    fn default() -> Self {
        KernelOptions {
            frames: 16,
            seed: 7,
            keep_last: false,
            real_compute: true,
            threads: 1,
            pool: TokenPool::new(64),
            precision: Precision::F32,
            wire: WireDtype::F32,
        }
    }
}

/// Frame counter handle shared with the sink kernels of one engine run.
pub type FramesSeen = std::sync::Arc<std::sync::atomic::AtomicU64>;

/// Construct kernels for every non-TX/RX actor of a device plan's local
/// subgraph.  Returns the kernels map (TX/RX slots left empty — the
/// distributed launcher fills them in) plus the sink frame counter.
pub fn make_kernels(
    meta: &ModelMeta,
    plan_graph: &AppGraph,
    service: &XlaService,
    opts: &KernelOptions,
) -> Result<(BTreeMap<String, Box<dyn ActorKernel>>, FramesSeen)> {
    let frames_seen: FramesSeen = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut kernels: BTreeMap<String, Box<dyn ActorKernel>> = BTreeMap::new();
    for (ai, actor) in plan_graph.actors.iter().enumerate() {
        let name = actor.name.clone();
        if name.starts_with("__tx") || name.starts_with("__rx") {
            continue; // bound by the launcher
        }
        let out_ports = plan_graph
            .out_edges(crate::dataflow::ActorId(ai))
            .len();
        let base = base_name(&name);
        let kernel: Box<dyn ActorKernel> = if base == "input" {
            Box::new(SourceKernel::new(
                opts.frames,
                meta.input_bytes(),
                out_ports,
                opts.seed ^ (ai as u64),
            ))
        } else if base == "sink" || base == "feedback" {
            // `feedback` is the Sec IV.D completion-signal receiver on the
            // endpoint (the paper's feedback socket from L4-L5).
            let k = SinkKernel::new(frames_seen.clone());
            Box::new(if opts.keep_last { k.keeping_last() } else { k })
        } else if let Some(entry) = meta.hlo_entries.get(base) {
            let out_token_bytes: Vec<usize> =
                actor.out_ports.iter().map(|p| p.token_bytes).collect();
            match real_layer_kernel(entry, service, opts, &out_token_bytes)? {
                Some(k) => Box::new(k) as Box<dyn ActorKernel>,
                None => Box::new(XlaKernel::new(service.clone(), base, out_token_bytes)),
            }
        } else if let Some(idx) = base.strip_prefix("prior") {
            let i: usize = idx.parse().map_err(|_| anyhow!("bad prior actor {name}"))?;
            let tap = meta
                .taps
                .get(i)
                .ok_or_else(|| anyhow!("prior{i} has no tap metadata"))?;
            Box::new(PriorBoxKernel::new(i, tap.h, tap.w, tap.anchors, out_ports))
        } else if base.starts_with("locr") {
            Box::new(PassthroughKernel { out_ports })
        } else if base == "concat_loc" {
            Box::new(ConcatKernel { out_ports })
        } else if base == "concat_conf_softmax" {
            Box::new(ConcatSoftmaxKernel { classes: meta.num_classes, out_ports })
        } else if base == "box_decode" {
            Box::new(BoxDecodeKernel { out_ports })
        } else if base == "nms" {
            Box::new(NmsKernel::ssd(meta.num_classes, out_ports))
        } else if base == "tracker" {
            Box::new(TrackerKernel::new(out_ports))
        } else {
            return Err(anyhow!("no kernel rule for actor {name}"));
        };
        kernels.insert(name, kernel);
    }
    Ok((kernels, frames_seen))
}

/// Build the real-compute kernel for one manifest layer, or `None` when
/// the caller should use the XLA executable instead: real compute
/// disabled, shapes fitting no Conv/DwConv/Dense geometry, or — the
/// fidelity rule — the layer's weight artifact existing on disk.  A
/// compiled HLO is ground truth for its weights (it may fuse pooling or
/// place activations where shape derivation cannot see them), so real
/// kernels never shadow it; they are the *no-artifact* stand-in, with
/// deterministic name-seeded synthetic parameters and matching token
/// shapes, which is what lets the dataflow stack run real arithmetic
/// without a PJRT toolchain.
fn real_layer_kernel(
    entry: &HloEntry,
    service: &XlaService,
    opts: &KernelOptions,
    out_token_bytes: &[usize],
) -> Result<Option<DnnLayerKernel>> {
    if !opts.real_compute || entry.in_shapes.len() != 1 {
        return Ok(None);
    }
    // The main weight is the largest declared tensor (entries may also
    // list a 1-D bias); derive the op from its shape.
    let Some(main_w) = entry.weights.iter().max_by_key(|w| tensor::numel(&w.shape)) else {
        return Ok(None);
    };
    let Some(op) = DnnOp::derive(&entry.in_shapes[0], &entry.out_shape, &main_w.shape) else {
        return Ok(None);
    };
    if service.root().join(&main_w.file).exists() {
        return Ok(None); // real artifact: the compiled executable wins
    }
    // Visible marker: a half-built artifacts dir (manifest present,
    // weight .bins missing) would otherwise emit plausible numbers
    // from made-up parameters with nothing in the logs saying so.
    eprintln!(
        "make_kernels: {}: real-compute stand-in, weight artifact {} absent \
         (name-seeded synthetic parameters)",
        entry.name, main_w.file
    );
    Ok(Some(DnnLayerKernel::with_synth_weights(
        &entry.name,
        op,
        opts.threads,
        opts.pool.clone(),
        out_token_bytes.to_vec(),
        opts.precision,
    )?))
}

/// Per-actor FLOPs for a (possibly instanced / spliced) plan graph.
pub fn flops_for_plan(meta: &ModelMeta, plan_graph: &AppGraph) -> BTreeMap<String, u64> {
    plan_graph
        .actors
        .iter()
        .filter_map(|a| {
            meta.hlo_entries
                .get(base_name(&a.name))
                .map(|e| (a.name.clone(), e.flops))
        })
        .collect()
}

/// Cost-table resolution for instanced actors ("l1#2" uses "l1" costs):
/// expands a device cost table to cover the plan graph's instance names.
pub fn expand_cost_table(
    device: &crate::runtime::device::DeviceModel,
    plan_graph: &AppGraph,
) -> crate::runtime::device::DeviceModel {
    let mut d = device.clone();
    for a in &plan_graph.actors {
        let base = base_name(&a.name);
        if base != a.name {
            if let Some(&ms) = device.cost_ms.get(base) {
                d.cost_ms.insert(a.name.clone(), ms);
            }
        }
    }
    d
}

/// A full single-device (local) run of a model: used by the quickstart
/// example and the local-baseline measurements of Figs 4-6.
pub fn run_local(
    meta: &ModelMeta,
    service: &XlaService,
    device: crate::runtime::device::DeviceModel,
    opts: &KernelOptions,
) -> Result<crate::runtime::metrics::RunReport> {
    let graph = build_graph(meta, DEFAULT_CAPACITY)?;
    let (kernels, _frames) = make_kernels(meta, &graph, service, opts)?;
    let device = expand_cost_table(&device, &graph);
    let mut engine = crate::runtime::engine::Engine::new(graph, device)?;
    engine.set_flops(meta.flops_map());
    engine.set_token_pool(opts.pool.clone());
    engine.run(kernels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::Manifest;
    use crate::runtime::device::DeviceModel;
    use crate::runtime::xla_exec::Variant;

    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn base_name_strips_instances() {
        assert_eq!(base_name("l1#2"), "l1");
        assert_eq!(base_name("conv1"), "conv1");
    }

    #[test]
    fn vehicle_graph_matches_fig2() {
        let Some(m) = manifest() else { return };
        let meta = m.model("vehicle").unwrap();
        let g = build_graph(meta, 4).unwrap();
        assert_eq!(g.actors.len(), 6);
        assert_eq!(g.edges.len(), 5);
        let order = g.topo_order().unwrap();
        assert_eq!(g.actor(order[0]).name, "input");
        assert_eq!(g.actor(*order.last().unwrap()).name, "sink");
    }

    #[test]
    fn ssd_graph_matches_fig3_counts() {
        let Some(m) = manifest() else { return };
        let meta = m.model("ssd").unwrap();
        let g = build_graph(meta, 4).unwrap();
        assert_eq!(g.actors.len(), 53);
        assert_eq!(g.edges.len(), 69);
        assert!(g.topo_order().is_ok());
        // Analyzer certifies the SSD graph consistent & deadlock-free.
        let report = crate::analyzer::analyze(&g).unwrap();
        assert!(report.repetition_vector.iter().all(|&q| q == 1));
    }

    #[test]
    fn vehicle_local_run_end_to_end() {
        let Some(m) = manifest() else { return };
        let meta = m.model("vehicle").unwrap();
        let svc = XlaService::spawn(&m.root, meta, Variant::Jnp).unwrap();
        let opts = KernelOptions { frames: 4, seed: 1, keep_last: true, ..Default::default() };
        let report = run_local(meta, &svc, DeviceModel::native("host"), &opts).unwrap();
        assert_eq!(report.frames, 4);
        assert_eq!(report.actors["l45"].firings, 4);
        assert_eq!(report.actors["input"].firings, 4);
    }

    #[test]
    fn unknown_actor_has_no_kernel_rule() {
        let Some(m) = manifest() else { return };
        let meta = m.model("vehicle").unwrap();
        let svc = XlaService::spawn(&m.root, meta, Variant::Jnp).unwrap();
        let mut g = AppGraph::new();
        g.add_spa("mystery");
        let err = make_kernels(meta, &g, &svc, &KernelOptions::default());
        assert!(err.is_err());
    }
}
