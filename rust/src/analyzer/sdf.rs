//! Balance equations / repetition vector for the static-rate view of the
//! graph (classic SDF consistency, the foundation VR-PRUNE builds on).
//!
//! For every edge (a --prod--> b --cons-->), a consistent graph satisfies
//! q[a] * prod == q[b] * cons for the smallest positive integer vector q.
//! Variable-rate ports are analyzed at their *upper* rate limit (url),
//! which is the worst case for buffer sizing; VR-PRUNE's design rules
//! guarantee the DPG internals stay consistent for any atr setting because
//! the shared atr makes both endpoints move together.

use crate::dataflow::AppGraph;

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

/// Rational q = num/den with lazy normalization.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Rat {
    num: u64,
    den: u64,
}

impl Rat {
    fn new(num: u64, den: u64) -> Self {
        let g = gcd(num, den).max(1);
        Rat { num: num / g, den: den / g }
    }
    fn mul(self, num: u64, den: u64) -> Self {
        Rat::new(self.num * num, self.den * den)
    }
}

#[derive(Debug, PartialEq)]
pub enum SdfError {
    Inconsistent {
        src: String,
        dst: String,
        prod: u32,
        cons: u32,
        q_src: (u64, u64),
        q_dst: (u64, u64),
    },
    Disconnected(String),
    Empty,
}

impl std::fmt::Display for SdfError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SdfError::Inconsistent { src, dst, prod, cons, q_src, q_dst } => write!(
                f,
                "rate-inconsistent graph at edge {src}->{dst}: {q_src:?} * {prod} != {q_dst:?} * {cons}"
            ),
            SdfError::Disconnected(actor) => {
                write!(f, "graph is not connected; actor {actor} unreachable from actor 0")
            }
            SdfError::Empty => write!(f, "empty graph"),
        }
    }
}

impl std::error::Error for SdfError {}

/// Smallest positive integer repetition vector; Err if rate-inconsistent.
pub fn repetition_vector(g: &AppGraph) -> Result<Vec<u64>, SdfError> {
    let n = g.actors.len();
    if n == 0 {
        return Err(SdfError::Empty);
    }
    // Undirected adjacency over edges with (prod, cons) at url.
    let mut adj: Vec<Vec<(usize, u64, u64)>> = vec![Vec::new(); n];
    for e in &g.edges {
        let prod = g.actors[e.src.actor.0].out_ports[e.src.port].rate.url as u64;
        let cons = g.actors[e.dst.actor.0].in_ports[e.dst.port].rate.url as u64;
        // q[dst] = q[src] * prod / cons
        adj[e.src.actor.0].push((e.dst.actor.0, prod, cons));
        adj[e.dst.actor.0].push((e.src.actor.0, cons, prod));
    }
    let mut q: Vec<Option<Rat>> = vec![None; n];
    // Propagate per connected component (distributed graphs may have
    // several weakly-connected pieces after partitioning).
    for start in 0..n {
        if q[start].is_some() {
            continue;
        }
        q[start] = Some(Rat::new(1, 1));
        let mut stack = vec![start];
        while let Some(i) = stack.pop() {
            let qi = q[i].unwrap();
            for &(j, num, den) in &adj[i] {
                let qj = qi.mul(num, den);
                match q[j] {
                    None => {
                        q[j] = Some(qj);
                        stack.push(j);
                    }
                    Some(existing) => {
                        if existing != qj {
                            return Err(SdfError::Inconsistent {
                                src: g.actors[i].name.clone(),
                                dst: g.actors[j].name.clone(),
                                prod: num as u32,
                                cons: den as u32,
                                q_src: (qi.num, qi.den),
                                q_dst: (existing.num, existing.den),
                            });
                        }
                    }
                }
            }
        }
    }
    // Scale to smallest integers: multiply by lcm of denominators.
    let l = q.iter().map(|r| r.unwrap().den).fold(1u64, lcm);
    let mut out: Vec<u64> = q.iter().map(|r| {
        let r = r.unwrap();
        r.num * (l / r.den)
    }).collect();
    let g0 = out.iter().copied().fold(0u64, gcd).max(1);
    for v in &mut out {
        *v /= g0;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{AppGraph, RateSpec};

    #[test]
    fn homogeneous_chain_is_all_ones() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 2);
        assert_eq!(repetition_vector(&g).unwrap(), vec![1, 1]);
    }

    #[test]
    fn multirate_chain() {
        // a --2:3--> b : q = [3, 2]
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        let sp = RateSpec::fixed(2);
        g.actors[a.0].out_ports.push(crate::dataflow::actor::PortSpec {
            rate: sp,
            token_bytes: 4,
        });
        g.actors[b.0].in_ports.push(crate::dataflow::actor::PortSpec {
            rate: RateSpec::fixed(3),
            token_bytes: 4,
        });
        g.edges.push(crate::dataflow::EdgeSpec {
            src: crate::dataflow::PortRef { actor: a, port: 0 },
            dst: crate::dataflow::PortRef { actor: b, port: 0 },
            capacity: 8,
            token_bytes: 4,
            initial_tokens: 0,
        });
        assert_eq!(repetition_vector(&g).unwrap(), vec![3, 2]);
    }

    #[test]
    fn inconsistent_triangle_rejected() {
        // a-1:1->b, b-1:1->c, a-2:1->c is inconsistent (q[c] would need to
        // be both 1 and 2).
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        let c = g.add_spa("c");
        g.connect(a, b, 4, 2);
        g.connect(b, c, 4, 2);
        g.connect_rated(a, c, 4, 4, RateSpec::fixed(2), 0);
        // Fix the dst side to rate 1 to make it truly asymmetric in effect:
        // connect_rated writes the same rate both sides, so instead tweak.
        g.actors[c.0].in_ports[1].rate = RateSpec::fixed(1);
        g.edges[2].capacity = 4;
        assert!(matches!(
            repetition_vector(&g),
            Err(SdfError::Inconsistent { .. })
        ));
    }

    #[test]
    fn disconnected_components_each_get_ones() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        let c = g.add_spa("c");
        let d = g.add_spa("d");
        g.connect(a, b, 4, 2);
        g.connect(c, d, 4, 2);
        assert_eq!(repetition_vector(&g).unwrap(), vec![1, 1, 1, 1]);
    }

    #[test]
    fn downsampler_upsampler_pair() {
        // a -1:2-> b -3:1-> c : q = [q_a, q_b, q_c] with q_a*1=q_b*2,
        // q_b*3=q_c*1 -> q = [2, 1, 3]
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        let c = g.add_spa("c");
        g.connect(a, b, 4, 4);
        g.actors[b.0].in_ports[0].rate = RateSpec::fixed(2);
        g.actors[a.0].out_ports[0].rate = RateSpec::fixed(1);
        g.connect(b, c, 4, 8);
        g.actors[b.0].out_ports[0].rate = RateSpec::fixed(3);
        g.actors[c.0].in_ports[0].rate = RateSpec::fixed(1);
        assert_eq!(repetition_vector(&g).unwrap(), vec![2, 1, 3]);
    }

    #[test]
    fn empty_graph_is_error() {
        assert_eq!(repetition_vector(&AppGraph::new()), Err(SdfError::Empty));
    }
}
