//! Graph analyzer (paper §III.C "Analyzer"): validates application graphs
//! against the VR-PRUNE design rules and performs the design-time
//! consistency analysis the paper attributes to the model of computation —
//! absence of deadlock and buffer overflow, rate-balance (repetition
//! vector) of the static part, and structural rules for dynamic processing
//! subgraphs (DPGs).

pub mod deadlock;
pub mod dpg;
pub mod sdf;

use crate::dataflow::AppGraph;

#[derive(Debug, Clone, PartialEq)]
pub struct AnalysisReport {
    pub repetition_vector: Vec<u64>,
    pub schedulable: bool,
    pub max_buffer_occupancy: Vec<usize>,
    pub dpg_count: usize,
}

/// Run the full analysis pipeline; Err(e) on any rule violation.
pub fn analyze(graph: &AppGraph) -> anyhow::Result<AnalysisReport> {
    graph.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    let dpgs = dpg::check_dpgs(graph).map_err(|e| anyhow::anyhow!("{e}"))?;
    let reps = sdf::repetition_vector(graph).map_err(|e| anyhow::anyhow!("{e}"))?;
    let sim = deadlock::simulate_iteration(graph, &reps).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(AnalysisReport {
        repetition_vector: reps,
        schedulable: true,
        max_buffer_occupancy: sim.max_occupancy,
        dpg_count: dpgs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::AppGraph;

    #[test]
    fn analyze_simple_chain() {
        let mut g = AppGraph::new();
        let a = g.add_spa("src");
        let b = g.add_spa("mid");
        let c = g.add_spa("snk");
        g.connect(a, b, 4, 2);
        g.connect(b, c, 4, 2);
        let rep = analyze(&g).unwrap();
        assert_eq!(rep.repetition_vector, vec![1, 1, 1]);
        assert!(rep.schedulable);
        assert_eq!(rep.dpg_count, 0);
    }
}
