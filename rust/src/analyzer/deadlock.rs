//! Deadlock / buffer-overflow analysis by abstract execution.
//!
//! Executes one complete iteration (each actor fires its repetition-vector
//! count) over abstract FIFO fill levels, using a demand-driven scheduler.
//! If the schedule stalls before completing the iteration, the graph
//! deadlocks under the given capacities; the per-edge max occupancy gives
//! the buffer bound certificate the paper's "design time analysis for
//! buffer overflow or deadlock" refers to.

use crate::dataflow::AppGraph;

#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Max tokens simultaneously resident per edge during the iteration.
    pub max_occupancy: Vec<usize>,
    /// Total firings executed per actor (== repetition vector on success).
    pub firings: Vec<u64>,
}

#[derive(Debug, PartialEq)]
pub enum DeadlockError {
    Deadlock { remaining: Vec<u64>, blocked: String },
}

impl std::fmt::Display for DeadlockError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeadlockError::Deadlock { remaining, blocked } => write!(
                f,
                "deadlock: iteration stalls with remaining firings {remaining:?}; \
                 blocked actors: {blocked}"
            ),
        }
    }
}

impl std::error::Error for DeadlockError {}

/// Simulate one iteration; Err on deadlock (incl. capacity-induced).
pub fn simulate_iteration(g: &AppGraph, reps: &[u64]) -> Result<SimResult, DeadlockError> {
    let n = g.actors.len();
    let mut fill: Vec<usize> = g.edges.iter().map(|e| e.initial_tokens).collect();
    let mut max_occ = fill.clone();
    let mut remaining: Vec<u64> = reps.to_vec();
    let mut fired: Vec<u64> = vec![0; n];

    // Port rates at url (worst case; matches sdf.rs).
    let prod_rate = |ei: usize| -> usize {
        let e = &g.edges[ei];
        g.actors[e.src.actor.0].out_ports[e.src.port].rate.url as usize
    };
    let cons_rate = |ei: usize| -> usize {
        let e = &g.edges[ei];
        g.actors[e.dst.actor.0].in_ports[e.dst.port].rate.url as usize
    };

    let can_fire = |a: usize, fill: &[usize], remaining: &[u64]| -> bool {
        if remaining[a] == 0 {
            return false;
        }
        for (ei, e) in g.edges.iter().enumerate() {
            if e.dst.actor.0 == a && fill[ei] < cons_rate(ei) {
                return false;
            }
            if e.src.actor.0 == a {
                // Self-loops both consume and produce; net space needed is
                // prod - (consumed this firing on the same edge).
                let consumed = if e.dst.actor.0 == a { cons_rate(ei) } else { 0 };
                if fill[ei] - consumed + prod_rate(ei) > e.capacity {
                    return false;
                }
            }
        }
        true
    };

    loop {
        let mut progressed = false;
        for a in 0..n {
            while can_fire(a, &fill, &remaining) {
                // Consume then produce.
                for (ei, e) in g.edges.iter().enumerate() {
                    if e.dst.actor.0 == a {
                        fill[ei] -= cons_rate(ei);
                    }
                }
                for (ei, e) in g.edges.iter().enumerate() {
                    if e.src.actor.0 == a {
                        fill[ei] += prod_rate(ei);
                        max_occ[ei] = max_occ[ei].max(fill[ei]);
                    }
                }
                remaining[a] -= 1;
                fired[a] += 1;
                progressed = true;
            }
        }
        if remaining.iter().all(|&r| r == 0) {
            return Ok(SimResult { max_occupancy: max_occ, firings: fired });
        }
        if !progressed {
            let blocked: Vec<String> = (0..n)
                .filter(|&a| remaining[a] > 0)
                .map(|a| g.actors[a].name.clone())
                .collect();
            return Err(DeadlockError::Deadlock {
                remaining,
                blocked: blocked.join(", "),
            });
        }
    }
}

/// Minimum per-edge capacities that keep the canonical schedule live:
/// runs the simulation with "infinite" capacities and reports max occupancy.
pub fn minimal_buffer_bounds(g: &AppGraph, reps: &[u64]) -> Result<Vec<usize>, DeadlockError> {
    let mut relaxed = g.clone();
    for e in &mut relaxed.edges {
        e.capacity = usize::MAX / 2;
    }
    simulate_iteration(&relaxed, reps).map(|r| r.max_occupancy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::sdf::repetition_vector;
    use crate::dataflow::{AppGraph, RateSpec};

    #[test]
    fn chain_completes_one_iteration() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        let c = g.add_spa("c");
        g.connect(a, b, 4, 1);
        g.connect(b, c, 4, 1);
        let reps = repetition_vector(&g).unwrap();
        let sim = simulate_iteration(&g, &reps).unwrap();
        assert_eq!(sim.firings, vec![1, 1, 1]);
        assert_eq!(sim.max_occupancy, vec![1, 1]);
    }

    #[test]
    fn cycle_without_initial_tokens_deadlocks() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 2);
        g.connect(b, a, 4, 2);
        let sim = simulate_iteration(&g, &[1, 1]);
        assert!(matches!(sim, Err(DeadlockError::Deadlock { .. })));
    }

    #[test]
    fn cycle_with_initial_token_is_live() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 2);
        g.connect_rated(b, a, 4, 2, RateSpec::fixed(1), 1);
        let sim = simulate_iteration(&g, &[1, 1]).unwrap();
        assert_eq!(sim.firings, vec![1, 1]);
    }

    #[test]
    fn undersized_capacity_detected_as_deadlock() {
        // a fires 3x per iteration producing 1 each; b consumes 3 at once.
        // capacity 2 < 3 means a cannot complete its firings: deadlock.
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 2);
        g.actors[a.0].out_ports[0].rate = RateSpec::fixed(1);
        g.actors[b.0].in_ports[0].rate = RateSpec::fixed(3);
        let reps = repetition_vector(&g).unwrap();
        assert_eq!(reps, vec![3, 1]);
        assert!(simulate_iteration(&g, &reps).is_err());
        // With capacity 3 the same graph is live.
        g.edges[0].capacity = 3;
        let sim = simulate_iteration(&g, &reps).unwrap();
        assert_eq!(sim.max_occupancy, vec![3]);
    }

    #[test]
    fn minimal_buffer_bounds_match_occupancy() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 1);
        g.actors[a.0].out_ports[0].rate = RateSpec::fixed(2);
        g.actors[b.0].in_ports[0].rate = RateSpec::fixed(4);
        let reps = repetition_vector(&g).unwrap(); // [2, 1]
        let bounds = minimal_buffer_bounds(&g, &reps).unwrap();
        assert_eq!(bounds, vec![4]);
    }

    #[test]
    fn self_loop_with_state_token() {
        // Tracker-style actor with a state self-edge: 1 initial token keeps
        // it live; occupancy never exceeds 1.
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let t = g.add_spa("tracker");
        g.connect(src, t, 4, 1);
        g.connect_rated(t, t, 4, 1, RateSpec::fixed(1), 1);
        let reps = repetition_vector(&g).unwrap();
        let sim = simulate_iteration(&g, &reps).unwrap();
        assert_eq!(sim.firings, vec![1, 1]);
    }
}
