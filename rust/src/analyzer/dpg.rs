//! Dynamic processing subgraph (DPG) design rules (paper §III.A):
//!
//! * DAs, DPAs and CAs may only appear within DPGs;
//! * a DPG consists of exactly one CA, exactly two DAs (the entry and exit
//!   boundary), and any number of DPAs and/or SPAs;
//! * the CA sets the current token rate within the DPG, so it must reach
//!   every variable-rate actor of its DPG (a control edge);
//! * variable-rate ports may only occur on DA / DPA / CA actors;
//! * edges may not cross between two different DPGs (a DPG couples to the
//!   static graph only through its DAs).
//!
//! Graphs following these rules are compile-time analyzable for
//! consistency (no deadlock / overflow for any atr setting), which is what
//! `analyzer::deadlock` then certifies at url.

use crate::dataflow::{ActorKind, AppGraph};
use std::collections::BTreeMap;

#[derive(Debug, PartialEq)]
pub enum DpgError {
    CaCount(usize, usize),
    DaCount(usize, usize),
    VariableRateOnStatic(String),
    CrossDpgEdge(String, String, usize, usize),
    CaUnreachable(usize, String, String),
}

impl std::fmt::Display for DpgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DpgError::CaCount(dpg, n) => {
                write!(f, "DPG {dpg}: must contain exactly one CA, found {n}")
            }
            DpgError::DaCount(dpg, n) => {
                write!(f, "DPG {dpg}: must contain exactly two DAs, found {n}")
            }
            DpgError::VariableRateOnStatic(actor) => {
                write!(f, "actor {actor}: variable-rate port on non-dynamic actor")
            }
            DpgError::CrossDpgEdge(src, dst, a, b) => {
                write!(f, "edge {src}->{dst} crosses between DPG {a} and DPG {b}")
            }
            DpgError::CaUnreachable(dpg, ca, target) => {
                write!(f, "DPG {dpg}: CA {ca} does not reach dynamic actor {target}")
            }
        }
    }
}

impl std::error::Error for DpgError {}

/// Validate all DPG rules; returns the number of DPGs.
pub fn check_dpgs(g: &AppGraph) -> Result<usize, DpgError> {
    let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, a) in g.actors.iter().enumerate() {
        if let Some(d) = a.dpg {
            groups.entry(d).or_default().push(i);
        }
        // Variable-rate ports only on dynamic actors.
        if a.kind == ActorKind::Spa {
            let any_var = a
                .in_ports
                .iter()
                .chain(a.out_ports.iter())
                .any(|p| !p.rate.is_static());
            if any_var {
                return Err(DpgError::VariableRateOnStatic(a.name.clone()));
            }
        }
    }

    // No edge may connect two *different* DPGs.
    for e in &g.edges {
        let sd = g.actors[e.src.actor.0].dpg;
        let dd = g.actors[e.dst.actor.0].dpg;
        if let (Some(x), Some(y)) = (sd, dd) {
            if x != y {
                return Err(DpgError::CrossDpgEdge(
                    g.actors[e.src.actor.0].name.clone(),
                    g.actors[e.dst.actor.0].name.clone(),
                    x,
                    y,
                ));
            }
        }
    }

    for (&dpg_id, members) in &groups {
        let count = |k: ActorKind| members.iter().filter(|&&i| g.actors[i].kind == k).count();
        let cas = count(ActorKind::Ca);
        if cas != 1 {
            return Err(DpgError::CaCount(dpg_id, cas));
        }
        let das = count(ActorKind::Da);
        if das != 2 {
            return Err(DpgError::DaCount(dpg_id, das));
        }
        // CA must reach every DA/DPA in its DPG through intra-DPG edges.
        let ca = members
            .iter()
            .copied()
            .find(|&i| g.actors[i].kind == ActorKind::Ca)
            .unwrap();
        let mut reach = vec![false; g.actors.len()];
        reach[ca] = true;
        let mut stack = vec![ca];
        while let Some(i) = stack.pop() {
            for e in &g.edges {
                if e.src.actor.0 == i
                    && g.actors[e.dst.actor.0].dpg == Some(dpg_id)
                    && !reach[e.dst.actor.0]
                {
                    reach[e.dst.actor.0] = true;
                    stack.push(e.dst.actor.0);
                }
            }
        }
        for &m in members {
            if matches!(g.actors[m].kind, ActorKind::Da | ActorKind::Dpa) && !reach[m] {
                return Err(DpgError::CaUnreachable(
                    dpg_id,
                    g.actors[ca].name.clone(),
                    g.actors[m].name.clone(),
                ));
            }
        }
    }
    Ok(groups.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{ActorSpec, AppGraph, RateSpec};

    /// A minimal legal DPG: src(SPA) -> DA-in -> DPA -> DA-out -> snk(SPA),
    /// with CA controlling DA-in, DPA, DA-out.
    fn legal_dpg() -> AppGraph {
        let mut g = AppGraph::new();
        let src = g.add_spa("src");
        let da_in = g.add_actor(ActorSpec::new("da_in", ActorKind::Da).in_dpg(0));
        let dpa = g.add_actor(ActorSpec::new("dpa", ActorKind::Dpa).in_dpg(0));
        let da_out = g.add_actor(ActorSpec::new("da_out", ActorKind::Da).in_dpg(0));
        let ca = g.add_actor(ActorSpec::new("ca", ActorKind::Ca).in_dpg(0));
        let snk = g.add_spa("snk");
        g.connect(src, da_in, 4, 2);
        g.connect_rated(da_in, dpa, 4, 4, RateSpec::variable(0, 2), 0);
        g.connect_rated(dpa, da_out, 4, 4, RateSpec::variable(0, 2), 0);
        g.connect(da_out, snk, 4, 2);
        // CA control edges.
        g.connect(ca, da_in, 4, 2);
        g.connect(ca, dpa, 4, 2);
        g.connect(ca, da_out, 4, 2);
        g
    }

    #[test]
    fn legal_dpg_passes() {
        let g = legal_dpg();
        assert_eq!(check_dpgs(&g).unwrap(), 1);
    }

    #[test]
    fn missing_ca_detected() {
        let mut g = legal_dpg();
        let ca = g.actor_by_name("ca").unwrap();
        g.actors[ca.0].kind = ActorKind::Dpa; // demote CA
        assert_eq!(check_dpgs(&g), Err(DpgError::CaCount(0, 0)));
    }

    #[test]
    fn wrong_da_count_detected() {
        let mut g = legal_dpg();
        let d = g.actor_by_name("dpa").unwrap();
        g.actors[d.0].kind = ActorKind::Da; // now 3 DAs
        assert_eq!(check_dpgs(&g), Err(DpgError::DaCount(0, 3)));
    }

    #[test]
    fn variable_rate_on_spa_detected() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect_rated(a, b, 4, 4, RateSpec::variable(0, 2), 0);
        assert_eq!(
            check_dpgs(&g),
            Err(DpgError::VariableRateOnStatic("a".into()))
        );
    }

    #[test]
    fn cross_dpg_edge_detected() {
        let mut g = AppGraph::new();
        let a = g.add_actor(ActorSpec::new("a", ActorKind::Dpa).in_dpg(0));
        let b = g.add_actor(ActorSpec::new("b", ActorKind::Dpa).in_dpg(1));
        g.connect(a, b, 4, 2);
        assert!(matches!(check_dpgs(&g), Err(DpgError::CrossDpgEdge(..))));
    }

    #[test]
    fn ca_must_reach_all_dynamic_actors() {
        let mut g = legal_dpg();
        // Remove CA -> dpa control edge (edge index 5).
        g.edges.remove(5);
        // Also remove da_in -> dpa so dpa is unreachable from CA entirely.
        g.edges.remove(1);
        assert!(matches!(check_dpgs(&g), Err(DpgError::CaUnreachable(..))));
    }

    #[test]
    fn static_graph_has_zero_dpgs() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 2);
        assert_eq!(check_dpgs(&g).unwrap(), 0);
    }
}
