//! Actor specifications (paper §III.A): every actor is one of four types —
//! static processing actor (SPA), dynamic actor (DA), configuration actor
//! (CA) or dynamic processing actor (DPA).  DA/CA/DPA may only appear
//! inside dynamic processing subgraphs (DPGs).

use super::rates::RateSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ActorId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActorKind {
    /// Static processing actor: fixed token rates on every port.
    Spa,
    /// Dynamic actor: the entry/exit boundary of a DPG, translating between
    /// static rates outside and variable rates inside.
    Da,
    /// Configuration actor: sets the current token rate within its DPG.
    Ca,
    /// Dynamic processing actor: variable-rate computation inside a DPG.
    Dpa,
}

#[derive(Debug, Clone)]
pub struct PortSpec {
    pub rate: RateSpec,
    /// Size of one token on this port, in bytes.
    pub token_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct ActorSpec {
    pub name: String,
    pub kind: ActorKind,
    /// DPG membership (None for the static part of the graph).
    pub dpg: Option<usize>,
    pub in_ports: Vec<PortSpec>,
    pub out_ports: Vec<PortSpec>,
}

impl ActorSpec {
    pub fn new(name: impl Into<String>, kind: ActorKind) -> Self {
        ActorSpec {
            name: name.into(),
            kind,
            dpg: None,
            in_ports: Vec::new(),
            out_ports: Vec::new(),
        }
    }

    pub fn in_dpg(mut self, dpg: usize) -> Self {
        self.dpg = Some(dpg);
        self
    }

    pub fn is_source(&self) -> bool {
        self.in_ports.is_empty()
    }

    pub fn is_sink(&self) -> bool {
        self.out_ports.is_empty()
    }

    /// SPA ports must all be static-rate (VR-PRUNE design rule).
    pub fn validate(&self) -> Result<(), String> {
        for (i, p) in self.in_ports.iter().chain(self.out_ports.iter()).enumerate() {
            p.rate.validate().map_err(|e| format!("{}: port {i}: {e}", self.name))?;
        }
        if self.kind == ActorKind::Spa {
            for p in self.in_ports.iter().chain(self.out_ports.iter()) {
                if !p.rate.is_static() {
                    return Err(format!(
                        "{}: SPA may not have variable-rate ports",
                        self.name
                    ));
                }
            }
        }
        if matches!(self.kind, ActorKind::Da | ActorKind::Ca | ActorKind::Dpa)
            && self.dpg.is_none()
        {
            return Err(format!(
                "{}: {:?} actors may only appear within a DPG",
                self.name, self.kind
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn port(rate: RateSpec) -> PortSpec {
        PortSpec { rate, token_bytes: 4 }
    }

    #[test]
    fn spa_rejects_variable_ports() {
        let mut a = ActorSpec::new("a", ActorKind::Spa);
        a.in_ports.push(port(RateSpec::variable(0, 2)));
        assert!(a.validate().is_err());
        let mut b = ActorSpec::new("b", ActorKind::Spa);
        b.in_ports.push(port(RateSpec::fixed(1)));
        assert!(b.validate().is_ok());
    }

    #[test]
    fn dynamic_actors_require_dpg() {
        let a = ActorSpec::new("ca", ActorKind::Ca);
        assert!(a.validate().is_err());
        let b = ActorSpec::new("ca", ActorKind::Ca).in_dpg(0);
        assert!(b.validate().is_ok());
    }

    #[test]
    fn source_sink_classification() {
        let mut src = ActorSpec::new("src", ActorKind::Spa);
        src.out_ports.push(port(RateSpec::fixed(1)));
        assert!(src.is_source() && !src.is_sink());
        let mut snk = ActorSpec::new("snk", ActorKind::Spa);
        snk.in_ports.push(port(RateSpec::fixed(1)));
        assert!(snk.is_sink() && !snk.is_source());
    }

    #[test]
    fn invalid_port_rate_propagates() {
        let mut a = ActorSpec::new("a", ActorKind::Spa);
        a.in_ports.push(port(RateSpec { lrl: 2, url: 1 }));
        assert!(a.validate().is_err());
    }
}
