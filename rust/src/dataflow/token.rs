//! Tokens: fixed-size data packets flowing through FIFO edges.  In the
//! machine-learning context a token is a tensor of intermediate features.
//! The payload is reference-counted so branch edges (SSD's six head taps)
//! broadcast without copying.
//!
//! [`TokenPool`] closes the allocation loop: consumed tokens whose
//! payload is no longer shared are reclaimed through `Arc::try_unwrap`
//! and their buffers handed back to producing kernels, so a pipeline in
//! steady state circulates a fixed set of buffers instead of allocating
//! one per firing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Debug, Clone)]
pub struct Token {
    /// Raw little-endian payload (f32 tensor bytes for DNN tokens).
    pub data: Arc<Vec<u8>>,
    /// Frame / iteration index the token belongs to (diagnostics + tests).
    pub seq: u64,
}

impl Token {
    pub fn new(data: Vec<u8>, seq: u64) -> Self {
        Token { data: Arc::new(data), seq }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Interpret the payload as f32s, always materializing a fresh
    /// `Vec`.  Steady-state readers should prefer [`Token::to_f32`],
    /// which borrows instead when the layout allows.
    pub fn as_f32(&self) -> Vec<f32> {
        crate::util::tensor::bytes_to_f32(&self.data)
    }

    /// Zero-copy f32 view of the payload when it is 4-byte aligned (and
    /// the target is little-endian, matching the wire layout); `None`
    /// otherwise.  Heap buffers are *usually* aligned well past 4, so
    /// the borrow is the overwhelmingly common case — but it is checked,
    /// never assumed.
    pub fn as_f32_slice(&self) -> Option<&[f32]> {
        crate::util::tensor::cast_f32_slice(&self.data)
    }

    /// The payload as f32s: borrowed when aligned, copied when not.
    /// This is what the hot kernels use so steady-state inference stops
    /// re-materializing every tensor it only reads.
    pub fn to_f32(&self) -> std::borrow::Cow<'_, [f32]> {
        match self.as_f32_slice() {
            Some(s) => std::borrow::Cow::Borrowed(s),
            None => std::borrow::Cow::Owned(self.as_f32()),
        }
    }

    pub fn from_f32(vals: &[f32], seq: u64) -> Self {
        Token::new(crate::util::tensor::f32_to_bytes(vals), seq)
    }

    /// Wire-encode this token's payload (raw little-endian f32 tensor
    /// bytes) at `dtype` into `out` — what a TX FIFO ships across a cut
    /// edge.  Errors when the payload is not a whole f32 tensor.  The
    /// receive side decodes with `wire::decode_to_f32_bytes`, restoring
    /// the legacy token layout before anything downstream sees it.
    pub fn encode_wire(
        &self,
        dtype: crate::runtime::wire::WireDtype,
        out: &mut Vec<u8>,
    ) -> anyhow::Result<()> {
        crate::runtime::wire::encode_f32_bytes(dtype, &self.data, out)
    }
}

// ------------------------------------------------------------------ pool

/// Running tallies of a pool's effectiveness (all monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` satisfied from a recycled buffer.
    pub hits: u64,
    /// `take` had to hand out a fresh (empty) buffer.
    pub misses: u64,
    /// Buffers returned to the pool.
    pub recycled: u64,
    /// Tokens offered back whose payload was still shared (broadcast
    /// edges) — dropped, not pooled.
    pub shared_drops: u64,
}

struct PoolInner {
    free: Mutex<Vec<Vec<u8>>>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled: AtomicU64,
    shared_drops: AtomicU64,
}

/// Shared, bounded free-list of token payload buffers.  Clones share
/// the same pool; a capacity of 0 disables pooling (`take` always
/// allocates, `recycle` always drops).
#[derive(Clone)]
pub struct TokenPool {
    inner: Arc<PoolInner>,
}

impl std::fmt::Debug for TokenPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("TokenPool").field("cap", &self.inner.cap).field("stats", &s).finish()
    }
}

impl TokenPool {
    pub fn new(cap: usize) -> Self {
        TokenPool {
            inner: Arc::new(PoolInner {
                // Pre-sized so steady-state recycle never grows the list.
                free: Mutex::new(Vec::with_capacity(cap)),
                cap,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                recycled: AtomicU64::new(0),
                shared_drops: AtomicU64::new(0),
            }),
        }
    }

    /// A pool that never retains anything (plain allocation semantics).
    pub fn disabled() -> Self {
        TokenPool::new(0)
    }

    /// An empty buffer with at least `len` bytes of capacity: recycled
    /// when one *fits*, freshly allocated otherwise.  The capacity
    /// match matters for graphs with heterogeneous token sizes (SSD
    /// mixes 16-byte shape descriptors with multi-hundred-KB
    /// activations): handing a tiny recycled buffer to a large
    /// producer would just reallocate it, while burning the tiny
    /// buffer's slot — so undersized buffers stay pooled for takers
    /// they fit.
    pub fn take(&self, len: usize) -> Vec<u8> {
        let recycled = {
            let mut free = self.inner.free.lock().unwrap();
            // Newest-first scan; swap_remove keeps the pop O(1).
            free.iter()
                .rposition(|b| b.capacity() >= len)
                .map(|i| free.swap_remove(i))
        };
        let mut buf = match recycled {
            Some(b) => {
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.inner.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(len)
            }
        };
        buf.clear();
        buf
    }

    /// Reclaim a consumed token's payload.  Succeeds only when this was
    /// the last reference (clones on branch edges keep it alive) and
    /// the pool has room.
    pub fn recycle(&self, token: Token) -> bool {
        match Arc::try_unwrap(token.data) {
            Ok(buf) => self.recycle_buf(buf),
            Err(_) => {
                self.inner.shared_drops.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Return a raw buffer to the pool (dropped when full or disabled).
    pub fn recycle_buf(&self, buf: Vec<u8>) -> bool {
        if self.inner.cap == 0 {
            return false;
        }
        let mut free = self.inner.free.lock().unwrap();
        if free.len() >= self.inner.cap {
            return false;
        }
        free.push(buf);
        drop(free);
        self.inner.recycled.fetch_add(1, Ordering::Relaxed);
        true
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            recycled: self.inner.recycled.load(Ordering::Relaxed),
            shared_drops: self.inner.shared_drops.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_roundtrip_f32() {
        let t = Token::from_f32(&[1.0, -2.5], 3);
        assert_eq!(t.len(), 8);
        assert_eq!(t.as_f32(), vec![1.0, -2.5]);
        assert_eq!(t.seq, 3);
    }

    #[test]
    fn clone_shares_payload() {
        let t = Token::new(vec![1, 2, 3], 0);
        let u = t.clone();
        assert!(Arc::ptr_eq(&t.data, &u.data));
    }

    #[test]
    fn to_f32_agrees_with_as_f32_and_borrows_when_aligned() {
        let t = Token::from_f32(&[0.5, -1.0, 7.25, 0.0], 1);
        let copied = t.as_f32();
        let view = t.to_f32();
        assert_eq!(&*view, &copied[..]);
        if let Some(s) = t.as_f32_slice() {
            assert_eq!(s.as_ptr() as usize, t.data.as_ptr() as usize, "borrow, not copy");
            assert!(matches!(view, std::borrow::Cow::Borrowed(_)));
        }
        // Ragged payloads never produce a borrowed view.
        let ragged = Token::new(vec![1, 2, 3], 0);
        assert!(ragged.as_f32_slice().is_none());
    }

    #[test]
    fn token_wire_round_trip() {
        use crate::runtime::wire::{decode_to_f32_bytes, WireDtype};
        let t = Token::from_f32(&[0.5, -1.25, 1.0, 0.0], 9);
        for dtype in [WireDtype::F32, WireDtype::F16, WireDtype::I8, WireDtype::SparseI8] {
            let mut enc = Vec::new();
            t.encode_wire(dtype, &mut enc).unwrap();
            let mut back = Vec::new();
            decode_to_f32_bytes(dtype, &enc, &mut back).unwrap();
            assert_eq!(back.len(), t.len(), "{dtype:?} length preserved");
            // Values survive within the dtype's precision (exactly for
            // f32; these specific values are f16-exact too; the lossy
            // dtypes are covered by their own codec tests).
            if dtype == WireDtype::F32 || dtype == WireDtype::F16 {
                assert_eq!(Token::new(back, 9).as_f32(), t.as_f32(), "{dtype:?}");
            }
        }
        // Ragged (non-f32) payloads refuse to encode.
        let ragged = Token::new(vec![1, 2, 3], 0);
        assert!(ragged.encode_wire(WireDtype::I8, &mut Vec::new()).is_err());
    }

    #[test]
    fn pool_recycles_unshared_tokens() {
        let pool = TokenPool::new(4);
        let t = Token::new(Vec::with_capacity(64), 0);
        assert!(pool.recycle(t));
        let buf = pool.take(16);
        assert!(buf.capacity() >= 64, "recycled buffer keeps its capacity");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.recycled), (1, 0, 1));
    }

    #[test]
    fn pool_drops_shared_tokens() {
        let pool = TokenPool::new(4);
        let t = Token::new(vec![1, 2, 3], 0);
        let _broadcast_clone = t.clone();
        assert!(!pool.recycle(t), "shared payloads cannot be reclaimed");
        assert_eq!(pool.stats().shared_drops, 1);
    }

    #[test]
    fn take_matches_by_capacity_not_lifo() {
        let pool = TokenPool::new(4);
        assert!(pool.recycle_buf(Vec::with_capacity(8)));
        // A big take must not burn the small buffer on a realloc...
        let big = pool.take(1024);
        assert!(big.capacity() >= 1024);
        assert_eq!(pool.stats().misses, 1, "small buffer left pooled");
        // ...so a later small take still hits it.
        let small = pool.take(4);
        assert!(small.capacity() >= 8);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn pool_respects_capacity_and_disabled() {
        let pool = TokenPool::new(1);
        assert!(pool.recycle_buf(vec![1]));
        assert!(!pool.recycle_buf(vec![2]), "full pool drops");
        let off = TokenPool::disabled();
        assert!(!off.recycle_buf(vec![3]));
        assert!(off.take(8).is_empty());
        assert_eq!(off.stats().misses, 1);
    }

    #[test]
    fn pool_clones_share_buffers() {
        let a = TokenPool::new(4);
        let b = a.clone();
        assert!(a.recycle_buf(Vec::with_capacity(32)));
        assert!(b.take(8).capacity() >= 32);
    }
}
