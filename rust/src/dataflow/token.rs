//! Tokens: fixed-size data packets flowing through FIFO edges.  In the
//! machine-learning context a token is a tensor of intermediate features.
//! The payload is reference-counted so branch edges (SSD's six head taps)
//! broadcast without copying.

use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct Token {
    /// Raw little-endian payload (f32 tensor bytes for DNN tokens).
    pub data: Arc<Vec<u8>>,
    /// Frame / iteration index the token belongs to (diagnostics + tests).
    pub seq: u64,
}

impl Token {
    pub fn new(data: Vec<u8>, seq: u64) -> Self {
        Token { data: Arc::new(data), seq }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Interpret the payload as f32s, always materializing a fresh
    /// `Vec`.  Steady-state readers should prefer [`Token::to_f32`],
    /// which borrows instead when the layout allows.
    pub fn as_f32(&self) -> Vec<f32> {
        crate::util::tensor::bytes_to_f32(&self.data)
    }

    /// Zero-copy f32 view of the payload when it is 4-byte aligned (and
    /// the target is little-endian, matching the wire layout); `None`
    /// otherwise.  Heap buffers are *usually* aligned well past 4, so
    /// the borrow is the overwhelmingly common case — but it is checked,
    /// never assumed.
    pub fn as_f32_slice(&self) -> Option<&[f32]> {
        crate::util::tensor::cast_f32_slice(&self.data)
    }

    /// The payload as f32s: borrowed when aligned, copied when not.
    /// This is what the hot kernels use so steady-state inference stops
    /// re-materializing every tensor it only reads.
    pub fn to_f32(&self) -> std::borrow::Cow<'_, [f32]> {
        match self.as_f32_slice() {
            Some(s) => std::borrow::Cow::Borrowed(s),
            None => std::borrow::Cow::Owned(self.as_f32()),
        }
    }

    pub fn from_f32(vals: &[f32], seq: u64) -> Self {
        Token::new(crate::util::tensor::f32_to_bytes(vals), seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_roundtrip_f32() {
        let t = Token::from_f32(&[1.0, -2.5], 3);
        assert_eq!(t.len(), 8);
        assert_eq!(t.as_f32(), vec![1.0, -2.5]);
        assert_eq!(t.seq, 3);
    }

    #[test]
    fn clone_shares_payload() {
        let t = Token::new(vec![1, 2, 3], 0);
        let u = t.clone();
        assert!(Arc::ptr_eq(&t.data, &u.data));
    }

    #[test]
    fn to_f32_agrees_with_as_f32_and_borrows_when_aligned() {
        let t = Token::from_f32(&[0.5, -1.0, 7.25, 0.0], 1);
        let copied = t.as_f32();
        let view = t.to_f32();
        assert_eq!(&*view, &copied[..]);
        if let Some(s) = t.as_f32_slice() {
            assert_eq!(s.as_ptr() as usize, t.data.as_ptr() as usize, "borrow, not copy");
            assert!(matches!(view, std::borrow::Cow::Borrowed(_)));
        }
        // Ragged payloads never produce a borrowed view.
        let ragged = Token::new(vec![1, 2, 3], 0);
        assert!(ragged.as_f32_slice().is_none());
    }
}
