//! Tokens: fixed-size data packets flowing through FIFO edges.  In the
//! machine-learning context a token is a tensor of intermediate features.
//! The payload is reference-counted so branch edges (SSD's six head taps)
//! broadcast without copying.

use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct Token {
    /// Raw little-endian payload (f32 tensor bytes for DNN tokens).
    pub data: Arc<Vec<u8>>,
    /// Frame / iteration index the token belongs to (diagnostics + tests).
    pub seq: u64,
}

impl Token {
    pub fn new(data: Vec<u8>, seq: u64) -> Self {
        Token { data: Arc::new(data), seq }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Interpret the payload as f32s (tokens are 4-byte aligned tensors).
    pub fn as_f32(&self) -> Vec<f32> {
        crate::util::tensor::bytes_to_f32(&self.data)
    }

    pub fn from_f32(vals: &[f32], seq: u64) -> Self {
        Token::new(crate::util::tensor::f32_to_bytes(vals), seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_roundtrip_f32() {
        let t = Token::from_f32(&[1.0, -2.5], 3);
        assert_eq!(t.len(), 8);
        assert_eq!(t.as_f32(), vec![1.0, -2.5]);
        assert_eq!(t.seq, 3);
    }

    #[test]
    fn clone_shares_payload() {
        let t = Token::new(vec![1, 2, 3], 0);
        let u = t.clone();
        assert!(Arc::ptr_eq(&t.data, &u.data));
    }
}
