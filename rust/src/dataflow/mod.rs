//! VR-PRUNE model of computation (paper §III.A).
//!
//! A DNN application is a directed graph G = (A, F): nodes are *actors*
//! (computation, e.g. DNN layers), edges are FIFO buffers carrying *tokens*
//! (tensors) in FIFO order.  An actor *fires* when every input port has at
//! least its active token rate (atr) of tokens available; firing consumes
//! atr tokens per input port and produces atr tokens per output port.
//!
//! Two features distinguish VR-PRUNE from plain SDF:
//! * **variable token rates** — each port carries a design-time fixed
//!   `lrl(p) <= url(p)` band and a runtime-settable `atr(p)` within it;
//! * **the symmetric token rate requirement** — `atr(p_a) == atr(p_b)` for
//!   the two endpoints of every edge, always.
//!
//! Actors are typed SPA / DA / CA / DPA; DA, DPA and CA may only appear
//! inside *dynamic processing subgraphs* (DPGs) that encapsulate the
//! variable-rate behaviour (validated by `crate::analyzer::dpg`).

pub mod actor;
pub mod graph;
pub mod rates;
pub mod token;

pub use actor::{ActorId, ActorKind, ActorSpec};
pub use graph::{AppGraph, EdgeId, EdgeSpec, GraphError, PortRef};
pub use rates::RateSpec;
pub use token::{PoolStats, Token, TokenPool};
