//! Variable token rates (paper §III.A): for each port p, design-time fixed
//! `lower rate limit lrl(p)` and `upper rate limit url(p)`, and a runtime
//! `active token rate atr(p)` with `lrl(p) <= atr(p) <= url(p)`.
//!
//! A *static* port has lrl == url (its atr can never vary) — this is what
//! SPA ports must use.  The runtime stores atr in an atomic cell so a CA
//! can set the rate of its DPG before each firing.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RateSpec {
    pub lrl: u32,
    pub url: u32,
}

impl RateSpec {
    /// Static rate: lrl == atr == url, the SDF special case.
    pub fn fixed(rate: u32) -> Self {
        RateSpec { lrl: rate, url: rate }
    }

    /// Variable rate band [lrl, url].
    pub fn variable(lrl: u32, url: u32) -> Self {
        RateSpec { lrl, url }
    }

    pub fn is_static(&self) -> bool {
        self.lrl == self.url
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.lrl > self.url {
            return Err(format!("lrl {} > url {}", self.lrl, self.url));
        }
        if self.url == 0 {
            return Err("url must be >= 1".to_string());
        }
        Ok(())
    }

    pub fn contains(&self, atr: u32) -> bool {
        self.lrl <= atr && atr <= self.url
    }
}

/// Runtime-shared active token rate cell.  One cell is shared by the two
/// ports of an edge, which *enforces* the symmetric token rate requirement
/// (atr(p_a) == atr(p_b)) by construction.
#[derive(Debug, Clone)]
pub struct AtrCell {
    spec: RateSpec,
    atr: Arc<AtomicU32>,
}

impl AtrCell {
    pub fn new(spec: RateSpec) -> Self {
        // Initial atr = url (the "full rate" default used by PRUNE).
        AtrCell { spec, atr: Arc::new(AtomicU32::new(spec.url)) }
    }

    pub fn spec(&self) -> RateSpec {
        self.spec
    }

    pub fn get(&self) -> u32 {
        self.atr.load(Ordering::Acquire)
    }

    /// Set the active rate; rejects values outside [lrl, url].
    pub fn set(&self, atr: u32) -> Result<(), String> {
        if !self.spec.contains(atr) {
            return Err(format!(
                "atr {atr} outside [{}, {}]",
                self.spec.lrl, self.spec.url
            ));
        }
        self.atr.store(atr, Ordering::Release);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_rate_is_static() {
        let r = RateSpec::fixed(2);
        assert!(r.is_static());
        assert!(r.validate().is_ok());
        assert!(r.contains(2) && !r.contains(1));
    }

    #[test]
    fn variable_band() {
        let r = RateSpec::variable(0, 3);
        assert!(!r.is_static());
        assert!(r.contains(0) && r.contains(3) && !r.contains(4));
    }

    #[test]
    fn invalid_specs_rejected() {
        assert!(RateSpec { lrl: 3, url: 1 }.validate().is_err());
        assert!(RateSpec { lrl: 0, url: 0 }.validate().is_err());
    }

    #[test]
    fn atr_cell_enforces_band() {
        let c = AtrCell::new(RateSpec::variable(1, 4));
        assert_eq!(c.get(), 4); // defaults to url
        c.set(2).unwrap();
        assert_eq!(c.get(), 2);
        assert!(c.set(0).is_err());
        assert!(c.set(5).is_err());
    }

    #[test]
    fn atr_cell_shared_between_clones() {
        // The shared cell is the mechanism behind the symmetric token rate
        // requirement: both edge endpoints observe the same atr.
        let a = AtrCell::new(RateSpec::variable(1, 8));
        let b = a.clone();
        a.set(3).unwrap();
        assert_eq!(b.get(), 3);
    }
}
