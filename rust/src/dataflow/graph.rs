//! Application graph G = (A, F): actors + FIFO buffer edges, with a
//! builder API used by the model definitions (`crate::models`) and by the
//! tests.  Validation covers port/edge consistency and the design-time
//! half of the symmetric token rate requirement (identical [lrl, url]
//! bands on the two endpoints of every edge — the runtime half, identical
//! atr, is enforced structurally by the shared `AtrCell`).

use super::actor::{ActorId, ActorKind, ActorSpec, PortSpec};
use super::rates::RateSpec;
use std::collections::{BTreeMap, VecDeque};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EdgeId(pub usize);

/// (actor, port index) endpoint of an edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PortRef {
    pub actor: ActorId,
    pub port: usize,
}

#[derive(Debug, Clone)]
pub struct EdgeSpec {
    pub src: PortRef,
    pub dst: PortRef,
    /// Maximum number of tokens the FIFO can hold at any moment.
    pub capacity: usize,
    pub token_bytes: usize,
    /// Initial tokens ("delays" in dataflow terms) — used by feedback
    /// edges such as the tracker's state self-edge.
    pub initial_tokens: usize,
}

#[derive(Debug, PartialEq)]
pub enum GraphError {
    UnknownActor(usize),
    Actor { actor: String, msg: String },
    Edge { src: String, dst: String, msg: String },
    Cycle(String),
    DuplicateName(String),
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::UnknownActor(id) => write!(f, "unknown actor id {id}"),
            GraphError::Actor { actor, msg } => write!(f, "actor {actor}: {msg}"),
            GraphError::Edge { src, dst, msg } => write!(f, "edge {src}->{dst}: {msg}"),
            GraphError::Cycle(actor) => {
                write!(f, "graph has a cycle with no initial tokens through actor {actor}")
            }
            GraphError::DuplicateName(name) => write!(f, "duplicate actor name {name}"),
        }
    }
}

impl std::error::Error for GraphError {}

#[derive(Debug, Clone, Default)]
pub struct AppGraph {
    pub actors: Vec<ActorSpec>,
    pub edges: Vec<EdgeSpec>,
}

impl AppGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_actor(&mut self, spec: ActorSpec) -> ActorId {
        self.actors.push(spec);
        ActorId(self.actors.len() - 1)
    }

    /// Convenience: add an SPA with no ports yet.
    pub fn add_spa(&mut self, name: &str) -> ActorId {
        self.add_actor(ActorSpec::new(name, ActorKind::Spa))
    }

    /// Connect `src` to `dst` with a fixed rate-1 edge carrying
    /// `token_bytes`-sized tokens; creates one new port on each side.
    pub fn connect(
        &mut self,
        src: ActorId,
        dst: ActorId,
        token_bytes: usize,
        capacity: usize,
    ) -> EdgeId {
        self.connect_rated(src, dst, token_bytes, capacity, RateSpec::fixed(1), 0)
    }

    pub fn connect_rated(
        &mut self,
        src: ActorId,
        dst: ActorId,
        token_bytes: usize,
        capacity: usize,
        rate: RateSpec,
        initial_tokens: usize,
    ) -> EdgeId {
        let sp = PortSpec { rate, token_bytes };
        self.actors[src.0].out_ports.push(sp.clone());
        let src_port = self.actors[src.0].out_ports.len() - 1;
        self.actors[dst.0].in_ports.push(sp);
        let dst_port = self.actors[dst.0].in_ports.len() - 1;
        self.edges.push(EdgeSpec {
            src: PortRef { actor: src, port: src_port },
            dst: PortRef { actor: dst, port: dst_port },
            capacity,
            token_bytes,
            initial_tokens,
        });
        EdgeId(self.edges.len() - 1)
    }

    pub fn actor(&self, id: ActorId) -> &ActorSpec {
        &self.actors[id.0]
    }

    pub fn actor_by_name(&self, name: &str) -> Option<ActorId> {
        self.actors.iter().position(|a| a.name == name).map(ActorId)
    }

    pub fn in_edges(&self, id: ActorId) -> Vec<(EdgeId, &EdgeSpec)> {
        self.edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.dst.actor == id)
            .map(|(i, e)| (EdgeId(i), e))
            .collect()
    }

    pub fn out_edges(&self, id: ActorId) -> Vec<(EdgeId, &EdgeSpec)> {
        self.edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.src.actor == id)
            .map(|(i, e)| (EdgeId(i), e))
            .collect()
    }

    /// Full structural validation: per-actor rules, unique names, port/edge
    /// agreement, symmetric rate bands, capacity sanity.
    pub fn validate(&self) -> Result<(), GraphError> {
        let mut names = BTreeMap::new();
        for (i, a) in self.actors.iter().enumerate() {
            if let Some(_prev) = names.insert(a.name.clone(), i) {
                return Err(GraphError::DuplicateName(a.name.clone()));
            }
            a.validate()
                .map_err(|msg| GraphError::Actor { actor: a.name.clone(), msg })?;
        }
        for e in &self.edges {
            let sa = self
                .actors
                .get(e.src.actor.0)
                .ok_or(GraphError::UnknownActor(e.src.actor.0))?;
            let da = self
                .actors
                .get(e.dst.actor.0)
                .ok_or(GraphError::UnknownActor(e.dst.actor.0))?;
            let err = |msg: String| GraphError::Edge {
                src: sa.name.clone(),
                dst: da.name.clone(),
                msg,
            };
            let sp = sa
                .out_ports
                .get(e.src.port)
                .ok_or_else(|| err(format!("missing src port {}", e.src.port)))?;
            let dp = da
                .in_ports
                .get(e.dst.port)
                .ok_or_else(|| err(format!("missing dst port {}", e.dst.port)))?;
            if sp.token_bytes != dp.token_bytes {
                return Err(err(format!(
                    "token size mismatch {} vs {}",
                    sp.token_bytes, dp.token_bytes
                )));
            }
            // Symmetric token rate requirement, design-time half: the rate
            // bands must be identical so atr(p_a) == atr(p_b) is satisfiable
            // for every setting.
            if sp.rate != dp.rate {
                return Err(err(format!(
                    "asymmetric rate bands [{},{}] vs [{},{}]",
                    sp.rate.lrl, sp.rate.url, dp.rate.lrl, dp.rate.url
                )));
            }
            if e.capacity == 0 {
                return Err(err("zero capacity".into()));
            }
            if e.capacity < e.src_rate_max(self) as usize {
                return Err(err(format!(
                    "capacity {} below max rate {}",
                    e.capacity,
                    e.src_rate_max(self)
                )));
            }
            if e.initial_tokens > e.capacity {
                return Err(err("initial tokens exceed capacity".into()));
            }
        }
        Ok(())
    }

    /// Precedence (topological) order, treating edges with initial tokens
    /// as broken (they are the legal way to close a cycle).  This is the
    /// ordering the Explorer uses to index partition points.
    pub fn topo_order(&self) -> Result<Vec<ActorId>, GraphError> {
        let n = self.actors.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for e in &self.edges {
            if e.initial_tokens > 0 || e.src.actor == e.dst.actor {
                continue; // feedback edge: pre-loaded, breaks the cycle
            }
            indeg[e.dst.actor.0] += 1;
            adj[e.src.actor.0].push(e.dst.actor.0);
        }
        let mut q: VecDeque<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = q.pop_front() {
            order.push(ActorId(i));
            for &j in &adj[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    q.push_back(j);
                }
            }
        }
        if order.len() != n {
            let stuck = (0..n).find(|&i| indeg[i] > 0).unwrap();
            return Err(GraphError::Cycle(self.actors[stuck].name.clone()));
        }
        Ok(order)
    }
}

impl EdgeSpec {
    fn src_rate_max(&self, g: &AppGraph) -> u32 {
        g.actors[self.src.actor.0].out_ports[self.src.port].rate.url
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain3() -> (AppGraph, ActorId, ActorId, ActorId) {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        let c = g.add_spa("c");
        g.connect(a, b, 16, 4);
        g.connect(b, c, 8, 4);
        (g, a, b, c)
    }

    #[test]
    fn build_and_validate_chain() {
        let (g, a, _, c) = chain3();
        g.validate().unwrap();
        assert!(g.actor(a).is_source());
        assert!(g.actor(c).is_sink());
        assert_eq!(g.edges.len(), 2);
    }

    #[test]
    fn topo_order_respects_edges() {
        let (g, a, b, c) = chain3();
        let order = g.topo_order().unwrap();
        let pos = |id: ActorId| order.iter().position(|&x| x == id).unwrap();
        assert!(pos(a) < pos(b) && pos(b) < pos(c));
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut g = AppGraph::new();
        g.add_spa("x");
        g.add_spa("x");
        assert!(matches!(g.validate(), Err(GraphError::DuplicateName(_))));
    }

    #[test]
    fn cycle_without_initial_tokens_detected() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 2);
        g.connect(b, a, 4, 2);
        assert!(matches!(g.topo_order(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn cycle_with_initial_tokens_allowed() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 2);
        g.connect_rated(b, a, 4, 2, RateSpec::fixed(1), 1);
        g.validate().unwrap();
        assert!(g.topo_order().is_ok());
    }

    #[test]
    fn zero_capacity_rejected() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect(a, b, 4, 0);
        assert!(matches!(g.validate(), Err(GraphError::Edge { .. })));
    }

    #[test]
    fn capacity_below_rate_rejected() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect_rated(a, b, 4, 2, RateSpec::fixed(4), 0);
        assert!(g.validate().is_err());
    }

    #[test]
    fn initial_tokens_above_capacity_rejected() {
        let mut g = AppGraph::new();
        let a = g.add_spa("a");
        let b = g.add_spa("b");
        g.connect_rated(a, b, 4, 2, RateSpec::fixed(1), 3);
        assert!(g.validate().is_err());
    }

    #[test]
    fn in_out_edge_queries() {
        let (g, _, b, _) = chain3();
        assert_eq!(g.in_edges(b).len(), 1);
        assert_eq!(g.out_edges(b).len(), 1);
    }

    #[test]
    fn actor_by_name() {
        let (g, a, ..) = chain3();
        assert_eq!(g.actor_by_name("a"), Some(a));
        assert_eq!(g.actor_by_name("zzz"), None);
    }
}
