//! Integration tests: compiler -> distributed runtime -> XLA execution,
//! end-to-end over real localhost TCP, plus the VR-PRUNE dynamic-rate
//! path (CA-driven atr changes) through the live engine.

use edge_prune::compiler::compile;
use edge_prune::dataflow::rates::AtrCell;
use edge_prune::dataflow::{ActorKind, ActorSpec, AppGraph, RateSpec, Token};
use edge_prune::models::builder::{build_graph, make_kernels, KernelOptions, DEFAULT_CAPACITY};
use edge_prune::models::manifest::Manifest;
use edge_prune::platform::{Mapping, PlatformGraph};
use edge_prune::runtime::device::DeviceModel;
use edge_prune::runtime::distributed::run_deployment;
use edge_prune::runtime::engine::Engine;
use edge_prune::runtime::kernels::{ActorKernel, FireOutcome, SinkKernel, SourceKernel};
use edge_prune::runtime::netsim::LinkModel;
use edge_prune::runtime::xla_exec::{Variant, XlaService};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn manifest() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
}

/// Full stack: manifest -> graph -> compiler (PP cut) -> two engines over
/// shaped TCP -> XLA actors -> frames complete on both sides.
#[test]
fn vehicle_distributed_over_shaped_link_completes() {
    let Some(m) = manifest() else { return };
    let meta = m.model("vehicle").unwrap().clone();
    let graph = build_graph(&meta, DEFAULT_CAPACITY).unwrap();
    let order: Vec<String> = graph
        .topo_order()
        .unwrap()
        .iter()
        .map(|&id| graph.actor(id).name.clone())
        .collect();
    let mut pg = PlatformGraph::new();
    pg.add_device(DeviceModel::native("e"));
    pg.add_device(DeviceModel::native("s"));
    pg.add_link("e", "s", LinkModel::new("eth", 11.2, 1.49));
    let mapping = Mapping::partition_point(&order, 3, "e", "s");
    let plan = compile(&graph, &pg, &mapping, 30_100).unwrap();

    let svc = XlaService::spawn(&m.root, &meta, Variant::Jnp).unwrap();
    let services: BTreeMap<String, XlaService> =
        ["e", "s"].iter().map(|d| (d.to_string(), svc.clone())).collect();
    let devices: BTreeMap<String, DeviceModel> =
        ["e", "s"].iter().map(|d| (d.to_string(), DeviceModel::native(d))).collect();
    let opts = KernelOptions { frames: 5, seed: 3, keep_last: false, ..Default::default() };
    let reports = run_deployment(&plan, &meta, &services, &devices, &opts).unwrap();
    assert_eq!(reports["e"].frames, 5);
    assert_eq!(reports["s"].actors["l45"].firings, 5);
    // The shaped 73728-B cut costs >= 6.5 ms/frame serialization.
    assert!(reports["e"].ms_per_frame() >= 6.0);
}

/// The dual-input three-device deployment (Sec IV.C) completes and the
/// join actor sees both branches.
#[test]
fn dual_input_three_devices() {
    let Some(m) = manifest() else { return };
    let vehicle = m.model("vehicle").unwrap();
    let meta = edge_prune::models::vehicle::dual_meta(vehicle).unwrap();
    let graph = build_graph(&meta, DEFAULT_CAPACITY).unwrap();
    let mut pg = PlatformGraph::new();
    for d in ["n2", "n270", "i7"] {
        pg.add_device(DeviceModel::native(d));
    }
    pg.add_link("n2", "i7", LinkModel::ideal());
    pg.add_link("n270", "i7", LinkModel::ideal());
    let plan = compile(&graph, &pg, &edge_prune::models::vehicle::dual_mapping(), 30_300).unwrap();
    assert_eq!(plan.cut_edges(), 2);

    let services: BTreeMap<String, XlaService> = ["n2", "n270", "i7"]
        .iter()
        .map(|d| (d.to_string(), XlaService::spawn(&m.root, &meta, Variant::Jnp).unwrap()))
        .collect();
    let devices: BTreeMap<String, DeviceModel> = ["n2", "n270", "i7"]
        .iter()
        .map(|d| (d.to_string(), DeviceModel::native(d)))
        .collect();
    let opts = KernelOptions { frames: 3, seed: 9, keep_last: false, ..Default::default() };
    let reports = run_deployment(&plan, &meta, &services, &devices, &opts).unwrap();
    assert_eq!(reports["i7"].actors["l45_dual"].firings, 3);
    assert_eq!(reports["n270"].actors["input#2"].firings, 3);
}

/// SSD graph runs locally end-to-end: all 53 actors fire, the tracker
/// emits track tokens, and frames complete.
#[test]
fn ssd_local_pipeline_end_to_end() {
    let Some(m) = manifest() else { return };
    let Ok(meta) = m.model("ssd") else { return };
    let meta = meta.clone();
    let graph = build_graph(&meta, DEFAULT_CAPACITY).unwrap();
    let svc = XlaService::spawn(&m.root, &meta, Variant::Jnp).unwrap();
    let opts = KernelOptions { frames: 2, seed: 21, keep_last: true, ..Default::default() };
    let (kernels, _) = make_kernels(&meta, &graph, &svc, &opts).unwrap();
    let engine = Engine::new(graph, DeviceModel::native("host")).unwrap();
    let report = engine.run(kernels).unwrap();
    assert_eq!(report.frames, 2);
    for a in ["conv1", "dwcl13", "conf5", "concat_loc", "box_decode", "nms", "tracker"] {
        assert_eq!(report.actors[a].firings, 2, "{a}");
    }
}

/// VR-PRUNE dynamic rates live: a CA lowers the atr of a DPG edge from 2
/// to 1 mid-stream; the symmetric-rate cell makes consumer and producer
/// flip together.
#[test]
fn ca_changes_active_token_rate_mid_stream() {
    let mut g = AppGraph::new();
    let src = g.add_actor(ActorSpec::new("src", ActorKind::Da).in_dpg(0));
    let dpa = g.add_actor(ActorSpec::new("dpa", ActorKind::Dpa).in_dpg(0));
    let snk = g.add_spa("snk");
    let e0 = g.connect_rated(src, dpa, 4, 16, RateSpec::variable(1, 2), 0);
    g.connect(dpa, snk, 4, 16);
    let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
    let atr: AtrCell = engine.atr(e0);
    assert_eq!(atr.get(), 2); // defaults to url

    struct RatedSource {
        emitted: u64,
        atr: AtrCell,
    }
    impl ActorKernel for RatedSource {
        fn fire(&mut self, _i: &[Vec<Token>], _s: u64) -> anyhow::Result<FireOutcome> {
            // After 3 firings the (in-line) CA drops the rate to 1.
            if self.emitted == 3 {
                self.atr.set(1).unwrap();
            }
            if self.emitted >= 6 {
                return Ok(FireOutcome::Stop);
            }
            self.emitted += 1;
            let n = self.atr.get();
            Ok(FireOutcome::Produced(vec![(0..n)
                .map(|_| vec![self.emitted as u8; 4])
                .collect()]))
        }
    }
    struct CountingDpa {
        consumed: Arc<AtomicU64>,
    }
    impl ActorKernel for CountingDpa {
        fn fire(&mut self, inputs: &[Vec<Token>], _s: u64) -> anyhow::Result<FireOutcome> {
            self.consumed.fetch_add(inputs[0].len() as u64, Ordering::Relaxed);
            Ok(FireOutcome::one_each(vec![inputs[0][0].data.to_vec()]))
        }
    }
    let consumed = Arc::new(AtomicU64::new(0));
    let frames = Arc::new(AtomicU64::new(0));
    let mut kernels: BTreeMap<String, Box<dyn ActorKernel>> = BTreeMap::new();
    kernels.insert("src".into(), Box::new(RatedSource { emitted: 0, atr: atr.clone() }));
    kernels.insert("dpa".into(), Box::new(CountingDpa { consumed: consumed.clone() }));
    kernels.insert("snk".into(), Box::new(SinkKernel::new(frames.clone())));
    let report = engine.run(kernels).unwrap();
    // 3 firings at rate 2 + 3 at rate 1 = 9 tokens produced & consumed.
    assert_eq!(consumed.load(Ordering::Relaxed), 9);
    assert!(report.actors["dpa"].firings >= 5, "rate flip must not stall");
}

/// Deployment-plan JSON is parseable and contains the TX/RX FIFO specs.
#[test]
fn deployment_plan_json_roundtrip() {
    let Some(m) = manifest() else { return };
    let meta = m.model("vehicle").unwrap().clone();
    let graph = build_graph(&meta, DEFAULT_CAPACITY).unwrap();
    let order: Vec<String> = graph
        .topo_order()
        .unwrap()
        .iter()
        .map(|&id| graph.actor(id).name.clone())
        .collect();
    let mut pg = PlatformGraph::new();
    pg.add_device(DeviceModel::native("e"));
    pg.add_device(DeviceModel::native("s"));
    pg.add_link("e", "s", LinkModel::ideal());
    let plan =
        compile(&graph, &pg, &Mapping::partition_point(&order, 2, "e", "s"), 30_500).unwrap();
    let text = plan.to_json().to_string();
    let parsed = edge_prune::util::json::Json::parse(&text).unwrap();
    let devices = parsed.get("devices").unwrap().arr().unwrap();
    assert_eq!(devices.len(), 2);
    let has_tx = text.contains("__tx1") && text.contains("__rx1");
    assert!(has_tx, "plan must name the spliced FIFO actors: {text}");
}

/// Backpressure: a slow consumer bounds the producer through the bounded
/// FIFO — max occupancy never exceeds capacity (analyzer's certificate
/// holds at runtime).
#[test]
fn backpressure_respects_capacity() {
    let mut g = AppGraph::new();
    let src = g.add_spa("src");
    let snk = g.add_spa("snk");
    g.connect(src, snk, 4, 2);
    let device = DeviceModel::native("d").with_cost("snk", 2.0);
    let engine = Engine::new(g, device).unwrap();
    let frames = Arc::new(AtomicU64::new(0));
    let mut kernels: BTreeMap<String, Box<dyn ActorKernel>> = BTreeMap::new();
    kernels.insert("src".into(), Box::new(SourceKernel::new(50, 4, 1, 1)));
    kernels.insert("snk".into(), Box::new(SinkKernel::new(frames.clone())));
    let report = engine.run(kernels).unwrap();
    assert_eq!(report.frames, 50);
    // Producer must have spent time blocked on the full FIFO.
    assert!(report.actors["src"].blocked_out.as_millis() > 10);
}
