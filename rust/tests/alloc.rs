//! Counting-allocator proof that steady-state `EngineShard::infer`
//! performs **zero heap allocations** per frame.
//!
//! This test binary installs a global allocator that counts every
//! `alloc`/`realloc`, warms a shard up (stage-weight `OnceLock` init,
//! arena sizing, pool priming), then runs 100 inferences and asserts
//! the counter did not move.  It lives alone in its own test target so
//! no concurrent test thread can perturb the counter.

use edge_prune::compiler::PlanKey;
use edge_prune::server::model::{
    client_prepare, compile_server_plan, expected_digest, make_input, EngineShard, MODEL_NAME,
};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_infer_performs_zero_allocations() {
    let plan = Arc::new(compile_server_plan(&PlanKey::new(MODEL_NAME, 2)).unwrap());
    let mut shard = EngineShard::new(plan);
    let input = make_input(5);
    let payload = client_prepare(&input, 2);
    let expected = expected_digest(&input);

    // Warmup: initializes the stage-weight OnceLock, establishes the
    // response buffer's capacity in the shard pool, and verifies
    // correctness outside the measured window.
    for _ in 0..5 {
        let out = shard.infer(&payload).unwrap();
        assert_eq!(out, expected);
        shard.recycle(out);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        let out = shard.infer(&payload).unwrap();
        shard.recycle(out);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state EngineShard::infer allocated {} times over 100 frames",
        after - before
    );
}
