//! Counting-allocator proof that steady-state `EngineShard::infer`
//! performs **zero heap allocations** per frame.
//!
//! This test binary installs a global allocator that counts every
//! `alloc`/`realloc`, warms a shard up (stage-weight `OnceLock` init,
//! arena sizing, pool priming), then runs 100 inferences and asserts
//! the counter did not move.  It lives alone in its own test target so
//! no concurrent test thread can perturb the counter.

use edge_prune::compiler::PlanKey;
use edge_prune::runtime::wire::{Precision, SessionCodec, WireDtype};
use edge_prune::server::model::{
    client_prepare, client_prepare_codec, compile_server_plan, expected_digest,
    expected_digest_codec, make_input, EngineShard, FrameScratch, MODEL_NAME,
};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The two tests in this binary share one global counter; the harness
/// runs tests on parallel threads, so each test holds this lock for its
/// ENTIRE body — warmup allocations included — or the other test's
/// setup would land inside this one's measured window.
static WINDOW: Mutex<()> = Mutex::new(());

fn exclusive() -> std::sync::MutexGuard<'static, ()> {
    // A poisoned lock only means the other test failed; the counter
    // itself is still sound.
    WINDOW.lock().unwrap_or_else(|p| p.into_inner())
}

#[test]
fn steady_state_infer_performs_zero_allocations() {
    let _window = exclusive();
    let plan = Arc::new(compile_server_plan(&PlanKey::new(MODEL_NAME, 2)).unwrap());
    let mut shard = EngineShard::new(plan);
    let input = make_input(5);
    let payload = client_prepare(&input, 2);
    let expected = expected_digest(&input);

    // Warmup: initializes the stage-weight OnceLock, establishes the
    // response buffer's capacity in the shard pool, and verifies
    // correctness outside the measured window.
    for _ in 0..5 {
        let out = shard.infer(&payload).unwrap();
        assert_eq!(out, expected);
        shard.recycle(out);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        let out = shard.infer(&payload).unwrap();
        shard.recycle(out);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state EngineShard::infer allocated {} times over 100 frames",
        after - before
    );
}

#[test]
fn traced_steady_state_infer_performs_zero_allocations() {
    // The flight recorder's hot path (span guards, per-layer kernel
    // spans, ring writes) must hold the zero-allocation property too:
    // the ring is pre-registered by `warm_recorder` and spans are Copy
    // into fixed slots, so a traced frame allocates exactly as much as
    // an untraced one — nothing.
    use edge_prune::runtime::trace::{self, Stage};
    if !cfg!(feature = "trace") {
        return;
    }
    let _window = exclusive();
    let plan = Arc::new(compile_server_plan(&PlanKey::new(MODEL_NAME, 2)).unwrap());
    let mut shard = EngineShard::new(plan);
    let input = make_input(5);
    let payload = client_prepare(&input, 2);
    let expected = expected_digest(&input);

    // Warmup: ring registration, trace-id seed, stage OnceLock, pool.
    trace::warm_recorder();
    trace::set_sampling(1);
    trace::set_enabled(true);
    for _ in 0..5 {
        let tid = trace::next_trace_id();
        let infer_span = trace::span(tid, 0, Stage::Infer, 0);
        trace::set_current(tid, infer_span.id());
        let out = shard.infer_wire(&payload, WireDtype::F32).unwrap();
        trace::clear_current();
        drop(infer_span);
        assert_eq!(out, expected);
        shard.recycle(out);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        let tid = trace::next_trace_id();
        let infer_span = trace::span(tid, 0, Stage::Infer, 0);
        trace::set_current(tid, infer_span.id());
        let out = shard.infer_wire(&payload, WireDtype::F32).unwrap();
        trace::clear_current();
        drop(infer_span);
        shard.recycle(out);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    // Restore the process default before releasing the window; draining
    // allocates, so it stays outside the measured region.
    trace::set_enabled(false);
    let _ = trace::drain();
    assert_eq!(
        after - before,
        0,
        "traced steady-state infer allocated {} times over 100 frames",
        after - before
    );
}

#[test]
fn two_shard_parallel_steady_state_stays_zero_alloc() {
    // Thread-per-core layout in miniature: each thread owns its shard
    // outright (state never crosses cores, like the server's reactor
    // shards), warms it, then both run their steady-state loops
    // concurrently inside one barrier-fenced window during which the
    // WHOLE process must not allocate — proving per-shard zero-alloc
    // holds under parallel execution, not just single-threaded.
    use edge_prune::platform::affinity::pin_to_core;
    let _window = exclusive();
    let barrier = Arc::new(std::sync::Barrier::new(3));
    let workers: Vec<_> = (0..2)
        .map(|core| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let _ = pin_to_core(core); // best-effort, like the server
                let plan =
                    Arc::new(compile_server_plan(&PlanKey::new(MODEL_NAME, 2)).unwrap());
                let mut shard = EngineShard::new(plan);
                let input = make_input(11 + core as u64);
                let payload = client_prepare(&input, 2);
                let expected = expected_digest(&input);
                for _ in 0..5 {
                    let out = shard.infer(&payload).unwrap();
                    assert_eq!(out, expected);
                    shard.recycle(out);
                }
                barrier.wait(); // warmup done
                barrier.wait(); // window open
                for _ in 0..100 {
                    let out = shard.infer(&payload).unwrap();
                    shard.recycle(out);
                }
                barrier.wait(); // window closed
                barrier.wait(); // hold until the counter is read
            })
        })
        .collect();
    barrier.wait(); // both shards warm
    let before = ALLOCS.load(Ordering::SeqCst);
    barrier.wait(); // open the window
    barrier.wait(); // both loops done
    let after = ALLOCS.load(Ordering::SeqCst);
    barrier.wait(); // release the threads (exit allocs stay outside)
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(
        after - before,
        0,
        "two-shard parallel steady state allocated {} times over 2x100 frames",
        after - before
    );
}

#[test]
fn steady_state_sparse_infer_performs_zero_allocations() {
    // The sparse-i8 path end to end: the client side quantizes,
    // thresholds (stack histogram), and emits the bitmap/RLE index
    // section into a reused FrameScratch buffer; the server side parses
    // the self-describing frame and scatters the kept coefficients into
    // its fixed tensor — none of it may touch the heap once warm.
    // compile_server_plan also warms the process-wide
    // sparsity-calibration table outside the measured window.
    let _window = exclusive();
    let codec = SessionCodec { wire: WireDtype::SparseI8, precision: Precision::Int8 };
    let plan = Arc::new(compile_server_plan(&PlanKey::new(MODEL_NAME, 2)).unwrap());
    let mut shard = EngineShard::with_precision(plan, Precision::Int8);
    let input = make_input(13);
    let payload = client_prepare_codec(&input, 2, codec);
    let expected = expected_digest_codec(&input, 2, codec);

    // Warmup: quantized stage-net OnceLock, sparsity calibration,
    // scratch + index-section capacities, pool.
    let mut scratch = FrameScratch::new();
    let mut client_payload = Vec::new();
    let mut client_expected = Vec::new();
    for _ in 0..5 {
        scratch.frame_codec_into(&input, 2, codec, &mut client_payload, &mut client_expected);
        assert_eq!(client_payload, payload);
        assert_eq!(client_expected, expected);
        let out = shard.infer_wire(&payload, WireDtype::SparseI8).unwrap();
        assert_eq!(out, expected);
        shard.recycle(out);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        scratch.frame_codec_into(&input, 2, codec, &mut client_payload, &mut client_expected);
        let out = shard.infer_wire(&client_payload, WireDtype::SparseI8).unwrap();
        shard.recycle(out);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state sparse infer loop allocated {} times over 100 frames",
        after - before
    );
}

#[test]
fn steady_state_quantized_infer_performs_zero_allocations() {
    // The int8 path end to end: the client side runs quantized stages
    // and wire-encodes (FrameScratch reuse), the server side decodes
    // the i8 payload and runs quantized stages (EngineShard scratch) —
    // none of it may touch the heap once warm.
    let _window = exclusive();
    let codec = SessionCodec { wire: WireDtype::I8, precision: Precision::Int8 };
    let plan = Arc::new(compile_server_plan(&PlanKey::new(MODEL_NAME, 2)).unwrap());
    let mut shard = EngineShard::with_precision(plan, Precision::Int8);
    let input = make_input(9);
    let payload = client_prepare_codec(&input, 2, codec);
    let expected = expected_digest_codec(&input, 2, codec);

    // Warmup: quantized stage-net OnceLock, scratch capacities, pool.
    let mut scratch = FrameScratch::new();
    let mut client_payload = Vec::new();
    let mut client_expected = Vec::new();
    for _ in 0..5 {
        scratch.frame_codec_into(&input, 2, codec, &mut client_payload, &mut client_expected);
        assert_eq!(client_payload, payload);
        assert_eq!(client_expected, expected);
        let out = shard.infer_wire(&payload, WireDtype::I8).unwrap();
        assert_eq!(out, expected);
        shard.recycle(out);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        scratch.frame_codec_into(&input, 2, codec, &mut client_payload, &mut client_expected);
        let out = shard.infer_wire(&client_payload, WireDtype::I8).unwrap();
        shard.recycle(out);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state quantized infer loop allocated {} times over 100 frames",
        after - before
    );
}
