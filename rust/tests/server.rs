//! End-to-end serving tests: `edge-prune serve` + `loadgen` in-process.
//!
//! Acceptance criteria covered here:
//! * >= 8 concurrent synthetic clients complete >= 100 inferences each
//!   against one server with zero lost requests;
//! * admission rejects surface as explicit errors (session capacity at
//!   handshake, queue-full as rejected responses);
//! * responses are verified byte-for-byte against local ground truth.

use edge_prune::runtime::netsim::LinkModel;
use edge_prune::server::loadgen::{run_loadgen, LoadgenConfig};
use edge_prune::server::protocol::{
    read_handshake_reply, read_response, write_handshake, write_request, Handshake, RespStatus,
};
use edge_prune::server::{Server, ServerConfig};
use std::net::TcpStream;
use std::time::Duration;

fn test_cfg() -> ServerConfig {
    ServerConfig {
        workers: 4,
        // Tests share the machine with the whole suite: skip pinning.
        pin_workers: false,
        ..ServerConfig::default()
    }
}

/// The headline acceptance test: 8 concurrent clients x 100 inferences,
/// mixed partition points, zero lost requests, all responses verified.
#[test]
fn eight_clients_hundred_inferences_zero_lost() {
    let server = Server::start(test_cfg()).unwrap();
    let addr = server.addr().to_string();

    // Two loadgen waves with different partition points run concurrently,
    // so the batch queue sees a same-plan population to coalesce AND a
    // competing plan to keep separate.
    let addr2 = addr.clone();
    let wave2 = std::thread::spawn(move || {
        run_loadgen(&LoadgenConfig {
            addr: addr2,
            clients: 4,
            requests: 100,
            pp: 2,
            seed: 1000,
            ..LoadgenConfig::default()
        })
    });
    let wave1 = run_loadgen(&LoadgenConfig {
        addr,
        clients: 4,
        requests: 100,
        pp: 3,
        seed: 2000,
        ..LoadgenConfig::default()
    })
    .unwrap();
    let wave2 = wave2.join().unwrap().unwrap();

    for (name, report) in [("pp3 wave", &wave1), ("pp2 wave", &wave2)] {
        assert_eq!(report.sessions_rejected, 0, "{name}");
        assert_eq!(report.ok, 400, "{name}: {}", report.summary());
        assert_eq!(report.errors, 0, "{name}");
        assert_eq!(report.rejected, 0, "{name}");
        assert_eq!(report.lost(), 0, "{name}");
        assert!(report.latency.quantile_ms(0.99) > 0.0, "{name}");
    }

    let metrics = server.shutdown();
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 800);
    assert_eq!(metrics.get("sessions_admitted").unwrap().int().unwrap(), 8);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
    // Two plans compiled (pp2 + pp3), cached across 8 sessions.  The
    // hit/miss split is racy on cold keys (concurrent sessions may all
    // miss before the first insert), but one lookup per session is not.
    assert_eq!(metrics.get("plans_compiled").unwrap().int().unwrap(), 2);
    let hits = metrics.get("plan_cache_hits").unwrap().int().unwrap();
    let misses = metrics.get("plan_cache_misses").unwrap().int().unwrap();
    assert_eq!(hits + misses, 8, "one cache lookup per session");
    // Batching happened at all (occupancy >= 1 by construction).
    assert!(metrics.get("batch_occupancy").unwrap().num().unwrap() >= 1.0);
}

/// Session admission: the (max_sessions + 1)-th concurrent session gets
/// an explicit capacity reject at handshake, and loadgen reports it.
#[test]
fn session_capacity_rejects_are_explicit() {
    let server = Server::start(ServerConfig { max_sessions: 2, ..test_cfg() }).unwrap();
    let addr = server.addr();

    // Hold two sessions open.
    let mut held = Vec::new();
    for i in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        write_handshake(
            &mut s,
            &Handshake { model: "synthetic".into(), pp: 1, client_id: format!("hold-{i}") },
        )
        .unwrap();
        assert!(read_handshake_reply(&mut s).unwrap().accepted);
        held.push(s);
    }
    // A loadgen wave now bounces off the session limit...
    let report = run_loadgen(&LoadgenConfig {
        addr: addr.to_string(),
        clients: 3,
        requests: 5,
        pp: 1,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.sessions_rejected, 3);
    assert_eq!(report.sent, 0);
    // ...and succeeds once the held sessions close.
    drop(held);
    std::thread::sleep(Duration::from_millis(100)); // teardown races the retry
    let report = run_loadgen(&LoadgenConfig {
        addr: addr.to_string(),
        clients: 2,
        requests: 5,
        pp: 1,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.sessions_rejected, 0);
    assert_eq!(report.ok, 10);
    server.shutdown();
}

/// Queue admission: with a tiny queue and slow drain, overflowing
/// requests come back as explicit `rejected` responses, never drops.
#[test]
fn queue_overflow_rejects_are_explicit_not_lost() {
    let server = Server::start(ServerConfig {
        workers: 1,
        max_queue: 2,
        max_batch: 1,
        batch_linger: Duration::from_millis(20),
        ..test_cfg()
    })
    .unwrap();
    // One client firing requests back-to-back without reading responses
    // immediately would need pipelining; instead: many clients at once.
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 8,
        requests: 25,
        pp: 1,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.sent, 200);
    assert_eq!(report.lost(), 0, "{}", report.summary());
    assert_eq!(report.errors, 0);
    assert_eq!(report.ok + report.rejected, 200);
    let metrics = server.shutdown();
    let rejected = metrics.get("requests_rejected").unwrap().int().unwrap() as u64;
    assert_eq!(rejected, report.rejected);
}

/// A shaped client link bounds loadgen throughput (the LinkShaper rides
/// the serving path end-to-end).
#[test]
fn shaped_uplink_bounds_request_rate() {
    let server = Server::start(test_cfg()).unwrap();
    // 4 KiB payload at 2 MB/s = ~2 ms serialization per request; 20
    // requests >= 40 ms wall even though the server is local.
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 1,
        requests: 20,
        pp: 1,
        link: Some(LinkModel::new("slow-uplink", 2.0, 0.0)),
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 20);
    assert!(
        report.wall >= Duration::from_millis(38),
        "shaped run finished in {:?}",
        report.wall
    );
    server.shutdown();
}

/// Malformed traffic after a valid handshake gets an error response and
/// the server stays healthy for the next session.
#[test]
fn bad_payload_gets_error_response_and_server_survives() {
    let server = Server::start(test_cfg()).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut s,
        &Handshake { model: "synthetic".into(), pp: 2, client_id: "mal".into() },
    )
    .unwrap();
    assert!(read_handshake_reply(&mut s).unwrap().accepted);
    write_request(&mut s, 1, &[0xAB; 16]).unwrap(); // wrong token size
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.status, RespStatus::Error);
    assert!(String::from_utf8(resp.body).unwrap().contains("expects"));
    drop(s);

    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 1,
        requests: 5,
        pp: 2,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 5);
    server.shutdown();
}
