//! End-to-end serving tests: `edge-prune serve` + `loadgen` in-process.
//!
//! Acceptance criteria covered here:
//! * >= 8 concurrent synthetic clients complete >= 100 inferences each
//!   against one server with zero lost requests;
//! * admission rejects surface as explicit errors (session capacity at
//!   handshake, queue-full as rejected responses);
//! * responses are verified byte-for-byte against local ground truth;
//! * fault tolerance (protocol v2): an abrupt link cut detaches the
//!   session, a RECONNECT replays unacknowledged responses exactly-once,
//!   chaos-mode loadgen loses nothing while killing links mid-run, and a
//!   full server kill + restart is absorbed by local fallback with a
//!   session-level availability metric exported.

use edge_prune::platform::procinfo::ensure_fd_headroom;
use edge_prune::runtime::health::HealthConfig;
use edge_prune::runtime::netsim::LinkModel;
use edge_prune::server::failover::{FailoverClient, FailoverConfig};
use edge_prune::server::loadgen::{run_loadgen, run_session_wave, LoadgenConfig, WaveConfig};
use edge_prune::server::model::{client_prepare, expected_digest, make_input};
use edge_prune::server::protocol::{
    encode_frame, encode_handshake, read_handshake_reply, read_response, write_frame,
    write_handshake, write_request, Handshake, ReqKind, RespStatus, Resume,
};
use edge_prune::server::{Server, ServerConfig};
use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

fn test_cfg() -> ServerConfig {
    ServerConfig {
        workers: 4,
        // Tests share the machine with the whole suite: skip pinning.
        pin_workers: false,
        ..ServerConfig::default()
    }
}

/// The headline acceptance test: 8 concurrent clients x 100 inferences,
/// mixed partition points, zero lost requests, all responses verified.
#[test]
fn eight_clients_hundred_inferences_zero_lost() {
    let server = Server::start(test_cfg()).unwrap();
    let addr = server.addr().to_string();

    // Two loadgen waves with different partition points run concurrently,
    // so the batch queue sees a same-plan population to coalesce AND a
    // competing plan to keep separate.
    let addr2 = addr.clone();
    let wave2 = std::thread::spawn(move || {
        run_loadgen(&LoadgenConfig {
            addr: addr2,
            clients: 4,
            requests: 100,
            pp: 2,
            seed: 1000,
            ..LoadgenConfig::default()
        })
    });
    let wave1 = run_loadgen(&LoadgenConfig {
        addr,
        clients: 4,
        requests: 100,
        pp: 3,
        seed: 2000,
        ..LoadgenConfig::default()
    })
    .unwrap();
    let wave2 = wave2.join().unwrap().unwrap();

    for (name, report) in [("pp3 wave", &wave1), ("pp2 wave", &wave2)] {
        assert_eq!(report.sessions_rejected, 0, "{name}");
        assert_eq!(report.ok, 400, "{name}: {}", report.summary());
        assert_eq!(report.errors, 0, "{name}");
        assert_eq!(report.rejected, 0, "{name}");
        assert_eq!(report.lost(), 0, "{name}");
        assert!(report.latency.quantile_ms(0.99) > 0.0, "{name}");
    }

    let metrics = server.shutdown();
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 800);
    assert_eq!(metrics.get("sessions_admitted").unwrap().int().unwrap(), 8);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
    // Three plans live in the cache: pp2 + pp3 compiled on demand, the
    // pp5 local-only fallback warmed alongside them.  The hit/miss split
    // is racy on cold keys (concurrent sessions may all miss before the
    // first insert), but one demand lookup per session is not, and
    // warming stays off the demand counters.
    assert_eq!(metrics.get("plans_compiled").unwrap().int().unwrap(), 3);
    assert_eq!(metrics.get("plans_warmed").unwrap().int().unwrap(), 1);
    let hits = metrics.get("plan_cache_hits").unwrap().int().unwrap();
    let misses = metrics.get("plan_cache_misses").unwrap().int().unwrap();
    assert_eq!(hits + misses, 8, "one cache lookup per session");
    // Batching happened at all (occupancy >= 1 by construction).
    assert!(metrics.get("batch_occupancy").unwrap().num().unwrap() >= 1.0);
}

/// Session admission: the (max_sessions + 1)-th concurrent session gets
/// an explicit capacity reject at handshake, and loadgen reports it.
#[test]
fn session_capacity_rejects_are_explicit() {
    let server = Server::start(ServerConfig { max_sessions: 2, ..test_cfg() }).unwrap();
    let addr = server.addr();

    // Hold two sessions open.
    let mut held = Vec::new();
    for i in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        write_handshake(&mut s, &Handshake::v2("synthetic", 1, &format!("hold-{i}"))).unwrap();
        assert!(read_handshake_reply(&mut s).unwrap().accepted);
        held.push(s);
    }
    // A loadgen wave now bounces off the session limit...
    let report = run_loadgen(&LoadgenConfig {
        addr: addr.to_string(),
        clients: 3,
        requests: 5,
        pp: 1,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.sessions_rejected, 3);
    assert_eq!(report.sent, 0);
    // ...and succeeds once the held sessions close cleanly (a plain drop
    // would detach-and-linger, still holding the slots).
    for mut s in held {
        write_frame(&mut s, 1, ReqKind::Bye, &[]).unwrap();
    }
    std::thread::sleep(Duration::from_millis(100)); // teardown races the retry
    let report = run_loadgen(&LoadgenConfig {
        addr: addr.to_string(),
        clients: 2,
        requests: 5,
        pp: 1,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.sessions_rejected, 0);
    assert_eq!(report.ok, 10);
    server.shutdown();
}

/// Queue admission: with a tiny queue and slow drain, overflowing
/// requests come back as explicit `rejected` responses, never drops.
#[test]
fn queue_overflow_rejects_are_explicit_not_lost() {
    let server = Server::start(ServerConfig {
        workers: 1,
        max_queue: 2,
        max_batch: 1,
        batch_linger: Duration::from_millis(20),
        ..test_cfg()
    })
    .unwrap();
    // One client firing requests back-to-back without reading responses
    // immediately would need pipelining; instead: many clients at once.
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 8,
        requests: 25,
        pp: 1,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.sent, 200);
    assert_eq!(report.lost(), 0, "{}", report.summary());
    assert_eq!(report.errors, 0);
    assert_eq!(report.ok + report.rejected, 200);
    let metrics = server.shutdown();
    let rejected = metrics.get("requests_rejected").unwrap().int().unwrap() as u64;
    assert_eq!(rejected, report.rejected);
}

/// A shaped client link bounds loadgen throughput (the LinkShaper rides
/// the serving path end-to-end).
#[test]
fn shaped_uplink_bounds_request_rate() {
    let server = Server::start(test_cfg()).unwrap();
    // 4 KiB payload at 2 MB/s = ~2 ms serialization per request; 20
    // requests >= 40 ms wall even though the server is local.
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 1,
        requests: 20,
        pp: 1,
        link: Some(LinkModel::new("slow-uplink", 2.0, 0.0)),
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 20);
    assert!(
        report.wall >= Duration::from_millis(38),
        "shaped run finished in {:?}",
        report.wall
    );
    server.shutdown();
}

/// Malformed traffic after a valid handshake gets an error response and
/// the server stays healthy for the next session.
#[test]
fn bad_payload_gets_error_response_and_server_survives() {
    let server = Server::start(test_cfg()).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "mal")).unwrap();
    assert!(read_handshake_reply(&mut s).unwrap().accepted);
    write_request(&mut s, 1, &[0xAB; 16]).unwrap(); // wrong token size
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.status, RespStatus::Error);
    assert!(String::from_utf8(resp.body).unwrap().contains("expects"));
    drop(s);

    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 1,
        requests: 5,
        pp: 2,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 5);
    server.shutdown();
}

/// The deterministic replay contract: kill the socket mid-stream with an
/// unacknowledged response outstanding, RECONNECT with `last_ack`, and
/// the server must (a) replay the unacked response from its retransmit
/// ring and (b) answer a client-side re-send from the ring — all without
/// re-executing, so N requested inferences execute exactly N times.
#[test]
fn mid_stream_replay_delivers_exactly_once() {
    let server = Server::start(test_cfg()).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "replay")).unwrap();
    let hs = read_handshake_reply(&mut s).unwrap();
    assert!(hs.accepted && !hs.resumed);
    let session_id = hs.session_id;
    let token = hs.token;

    // Two completed inferences, both responses received client-side.
    for seq in [1u64, 2] {
        let input = make_input(seq);
        write_request(&mut s, seq, &client_prepare(&input, 2)).unwrap();
        let resp = read_response(&mut s).unwrap().unwrap();
        assert_eq!(resp.req_id, seq);
        assert_eq!(resp.body, expected_digest(&input));
    }

    // Abrupt link cut — no BYE.  The session detaches, state retained.
    // (The short sleep lets the reader observe the EOF and detach before
    // the RECONNECT below, so the detach counter is deterministic; the
    // resume itself would also work as a takeover of a still-attached
    // session.)
    s.shutdown(std::net::Shutdown::Both).unwrap();
    drop(s);
    std::thread::sleep(Duration::from_millis(50));

    // A RECONNECT without the session's resume token is refused — the
    // sequential session id alone must not be enough to hijack a
    // session and drain its replay ring.
    let mut hijacker = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut hijacker,
        &Handshake::v2("synthetic", 2, "replay")
            .with_resume(Resume { session_id, token: token ^ 1, last_ack: 0 }),
    )
    .unwrap();
    let refused = read_handshake_reply(&mut hijacker).unwrap();
    assert!(!refused.accepted);
    assert!(refused.message.contains("token mismatch"), "{}", refused.message);
    drop(hijacker);

    // RECONNECT acknowledging only seq 1: the server replays seq 2 from
    // the retransmit ring before anything else.
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut s,
        &Handshake::v2("synthetic", 2, "replay")
            .with_resume(Resume { session_id, token, last_ack: 1 }),
    )
    .unwrap();
    let hs2 = read_handshake_reply(&mut s).unwrap();
    assert!(hs2.accepted && hs2.resumed, "resume refused: {}", hs2.message);
    assert_eq!(hs2.session_id, session_id);
    assert_eq!(hs2.token, token, "resume keeps the session credential");
    let replayed = read_response(&mut s).unwrap().unwrap();
    assert_eq!(replayed.req_id, 2);
    assert_eq!(replayed.body, expected_digest(&make_input(2)));

    // A client-side re-send of seq 2 is answered from the ring too.
    write_request(&mut s, 2, &client_prepare(&make_input(2), 2)).unwrap();
    let dup = read_response(&mut s).unwrap().unwrap();
    assert_eq!(dup.req_id, 2);
    assert_eq!(dup.body, expected_digest(&make_input(2)));

    // New work flows on the resumed session.
    let input = make_input(3);
    write_request(&mut s, 3, &client_prepare(&input, 2)).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.req_id, 3);
    assert_eq!(resp.body, expected_digest(&input));
    write_frame(&mut s, 4, ReqKind::Bye, &[]).unwrap();
    drop(s);

    let metrics = server.shutdown();
    // Exactly-once execution: 3 distinct inferences ran, despite seq 2
    // being delivered three times (original + attach replay + re-send).
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 3);
    assert_eq!(metrics.get("sessions_resumed").unwrap().int().unwrap(), 1);
    assert!(metrics.get("responses_replayed").unwrap().int().unwrap() >= 2);
    assert_eq!(metrics.get("sessions_detached").unwrap().int().unwrap(), 1);
}

/// Chaos loadgen: every client kills its own link every 5 requests; the
/// resilient client reconnects/resumes and nothing is ever lost.
#[test]
fn chaos_loadgen_zero_lost_with_link_kills() {
    let server = Server::start(test_cfg()).unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 3,
        requests: 20,
        pp: 2,
        chaos_kill_every: 5,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 60, "{}", report.summary());
    assert_eq!(report.lost(), 0);
    assert_eq!(report.errors, 0);
    assert!((report.service_availability() - 1.0).abs() < 1e-12);
    assert!(report.reconnects >= 9, "3 kills per client, got {}", report.reconnects);
    assert!(report.sessions_resumed >= 1);
    let metrics = server.shutdown();
    assert!(metrics.get("sessions_resumed").unwrap().int().unwrap() >= 1);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// The headline chaos scenario: the edge endpoint is killed and later
/// restarted mid-run.  The client must complete every requested
/// inference with zero losses — remote before the kill, local-fallback
/// during the outage, remote again after re-joining — and export a
/// session-level availability metric.
#[test]
fn server_kill_and_restart_loses_zero_inferences() {
    let server_a = Server::start(test_cfg()).unwrap();
    let mut fc = FailoverClient::new(FailoverConfig {
        addr: server_a.addr().to_string(),
        pp: 2,
        client_id: "chaos".into(),
        max_attempts: 1,
        reconnect_backoff: Duration::from_millis(1),
        read_timeout: Duration::from_secs(1),
        probe_every: 1,
        health: HealthConfig { down_after_failures: 2, ..HealthConfig::default() },
        ..FailoverConfig::default()
    });

    let mut outcomes = Vec::new();
    for i in 0..10u64 {
        let input = make_input(i);
        let (body, served) = fc.infer(&input).unwrap();
        assert_eq!(body, expected_digest(&input), "frame {i}");
        outcomes.push(served);
    }
    assert!(outcomes.iter().all(|s| !s.is_local()), "healthy phase is all-remote");

    // Kill the edge endpoint mid-run.
    let _ = server_a.shutdown();
    for i in 10..20u64 {
        let input = make_input(i);
        let (body, served) = fc.infer(&input).unwrap();
        assert_eq!(body, expected_digest(&input), "frame {i} during outage");
        outcomes.push(served);
    }
    assert!(
        outcomes[10..].iter().all(|s| s.is_local()),
        "outage phase is served by the local-only fallback plan"
    );

    // Restart the edge (new process = new state, old session is gone);
    // the client re-joins collaborative inference via a fresh handshake.
    let server_b = Server::start(test_cfg()).unwrap();
    fc.set_addr(&server_b.addr().to_string());
    for i in 20..30u64 {
        let input = make_input(i);
        let (body, served) = fc.infer(&input).unwrap();
        assert_eq!(body, expected_digest(&input), "frame {i} after restart");
        outcomes.push(served);
    }
    assert!(
        outcomes[20..].iter().any(|s| !s.is_local()),
        "client re-joins collaborative inference after the restart"
    );
    fc.finish();

    // Zero losses, availability exported.
    let stats = fc.stats();
    assert_eq!(stats.requested, 30);
    assert_eq!(stats.completed, 30);
    assert_eq!(stats.served_local + stats.served_remote, 30);
    assert!(stats.served_local >= 10, "outage frames were local");
    assert!(stats.served_remote >= 11, "both remote phases served");
    assert!((stats.service_availability() - 1.0).abs() < 1e-12);
    assert!(stats.link_availability() < 1.0);
    let j = fc.metrics_json();
    assert!((j.get("service_availability").unwrap().num().unwrap() - 1.0).abs() < 1e-12);
    assert!(j.get("health").is_ok());

    let metrics = server_b.shutdown();
    assert!(metrics.get("requests_completed").unwrap().int().unwrap() >= 10);
}

/// Reactor partial-delivery: a handshake dribbled in one byte at a
/// time, then an inference frame split at awkward boundaries (header
/// byte-by-byte, payload in ragged chunks) — the resumable codecs must
/// reassemble both and the response must verify.
#[test]
fn one_byte_writes_reassemble_into_frames() {
    let server = Server::start(test_cfg()).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_nodelay(true).unwrap();
    let hs_bytes = encode_handshake(&Handshake::v2("synthetic", 2, "dribble")).unwrap();
    for b in &hs_bytes {
        s.write_all(&[*b]).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let reply = read_handshake_reply(&mut s).unwrap();
    assert!(reply.accepted, "{}", reply.message);

    let input = make_input(77);
    let frame = encode_frame(1, ReqKind::Infer, &client_prepare(&input, 2)).unwrap();
    // Header one byte at a time...
    for b in &frame[..13] {
        s.write_all(&[*b]).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    // ...then the payload in three ragged chunks.
    let body = &frame[13..];
    let cuts = [body.len() / 3, 2 * body.len() / 3, body.len()];
    let mut start = 0;
    for cut in cuts {
        s.write_all(&body[start..cut]).unwrap();
        start = cut;
        std::thread::sleep(Duration::from_millis(2));
    }
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.req_id, 1);
    assert_eq!(resp.status, RespStatus::Ok);
    assert_eq!(resp.body, expected_digest(&input));
    write_frame(&mut s, 2, ReqKind::Bye, &[]).unwrap();
    drop(s);
    let metrics = server.shutdown();
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 1);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// Slow-reader backpressure: a RECONNECT whose attach replays a full
/// retransmit ring queues more bytes than the (deliberately tiny)
/// write high-water mark in one burst, so the reactor must pause that
/// connection's reads and resume once the backlog drains — observable
/// as the `read_pauses` counter, with every replayed byte intact.
#[test]
fn replay_burst_crosses_high_water_and_pauses_reads() {
    let server = Server::start(ServerConfig {
        write_high_water: 4096, // ~64 retained responses far exceed this
        ..test_cfg()
    })
    .unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "slow")).unwrap();
    let hs = read_handshake_reply(&mut s).unwrap();
    assert!(hs.accepted);
    // Fill the replay ring past capacity (64): the newest 64 retained.
    for seq in 1..=70u64 {
        let input = make_input(seq);
        write_request(&mut s, seq, &client_prepare(&input, 2)).unwrap();
        let resp = read_response(&mut s).unwrap().unwrap();
        assert_eq!(resp.body, expected_digest(&make_input(seq)));
    }
    // Abrupt cut, then a RECONNECT acknowledging nothing: the server
    // replays all 64 retained responses in one attach burst.
    s.shutdown(std::net::Shutdown::Both).unwrap();
    drop(s);
    std::thread::sleep(Duration::from_millis(50));
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut s,
        &Handshake::v2("synthetic", 2, "slow")
            .with_resume(Resume { session_id: hs.session_id, token: hs.token, last_ack: 0 }),
    )
    .unwrap();
    let reply = read_handshake_reply(&mut s).unwrap();
    assert!(reply.accepted && reply.resumed, "{}", reply.message);
    // Ring capacity 64 kept seqs 7..=70, replayed in order.
    for seq in 7..=70u64 {
        let resp = read_response(&mut s).unwrap().unwrap();
        assert_eq!(resp.req_id, seq, "replay order");
        assert_eq!(resp.body, expected_digest(&make_input(seq)), "replay bytes intact");
    }
    write_frame(&mut s, 71, ReqKind::Bye, &[]).unwrap();
    drop(s);
    let metrics = server.shutdown();
    assert!(
        metrics.get("read_pauses").unwrap().int().unwrap() >= 1,
        "the 9 KiB replay burst must cross the 4 KiB high-water mark"
    );
    assert!(metrics.get("responses_replayed").unwrap().int().unwrap() >= 64);
    // Exactly-once: 70 executions despite 64 redeliveries.
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 70);
}

/// A disconnect in the middle of a frame (header half-sent) is link
/// loss, not corruption: the session detaches with its replay state
/// intact and a RECONNECT carries on with fresh work.
#[test]
fn mid_frame_disconnect_detaches_not_corrupts() {
    let server = Server::start(test_cfg()).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "torn")).unwrap();
    let hs = read_handshake_reply(&mut s).unwrap();
    assert!(hs.accepted);
    // One complete inference first, so the session has state worth
    // corrupting.
    let input = make_input(5);
    write_request(&mut s, 1, &client_prepare(&input, 2)).unwrap();
    assert_eq!(read_response(&mut s).unwrap().unwrap().body, expected_digest(&input));
    // Half a frame header, then a hard cut.
    let frame = encode_frame(2, ReqKind::Infer, &client_prepare(&make_input(6), 2)).unwrap();
    s.write_all(&frame[..7]).unwrap();
    s.shutdown(std::net::Shutdown::Both).unwrap();
    drop(s);
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(server.detached_sessions(), 1, "torn frame detached, did not close");
    // RECONNECT: the half-frame is gone with its connection; new work
    // (reusing the seq the torn frame never delivered) runs cleanly.
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut s,
        &Handshake::v2("synthetic", 2, "torn")
            .with_resume(Resume { session_id: hs.session_id, token: hs.token, last_ack: 1 }),
    )
    .unwrap();
    let reply = read_handshake_reply(&mut s).unwrap();
    assert!(reply.accepted && reply.resumed, "{}", reply.message);
    let input = make_input(6);
    write_request(&mut s, 2, &client_prepare(&input, 2)).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.req_id, 2);
    assert_eq!(resp.body, expected_digest(&input));
    write_frame(&mut s, 3, ReqKind::Bye, &[]).unwrap();
    drop(s);
    let metrics = server.shutdown();
    assert_eq!(metrics.get("sessions_detached").unwrap().int().unwrap(), 1);
    assert_eq!(metrics.get("sessions_resumed").unwrap().int().unwrap(), 1);
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 2);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// The per-session thread ceiling is gone: one reactor holds 512
/// concurrent sessions (fd limit permitting — scaled down only if the
/// environment refuses the headroom) on a fixed thread inventory, with
/// every response verified and zero losses.
#[test]
fn accept_smoke_512_concurrent_sessions_fixed_threads() {
    // 512 server + 512 client fds in one process, plus slack.
    let headroom = ensure_fd_headroom(2048).unwrap();
    let sessions = if headroom >= 1300 { 512 } else { 128 };
    let server = Server::start(ServerConfig {
        max_sessions: sessions + 8,
        max_queue: 4096,
        ..test_cfg()
    })
    .unwrap();
    assert_eq!(server.thread_count(), 6, "reactor + dispatcher + 4 workers, session-invariant");
    let report = run_session_wave(&WaveConfig {
        addr: server.addr().to_string(),
        sessions,
        rounds: 2,
        pp: 2,
        seed: 31,
        ..WaveConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, sessions as u64 * 2, "every inference verified");
    assert_eq!(report.errors, 0);
    let metrics = server.shutdown();
    assert_eq!(metrics.get("sessions_admitted").unwrap().int().unwrap(), sessions as i64);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
    assert_eq!(
        metrics.get("requests_completed").unwrap().int().unwrap(),
        sessions as i64 * 2
    );
}

/// Detached sessions hold their slot only for the linger window; the
/// reaper then frees it and a RECONNECT is told the session is gone.
#[test]
fn detached_sessions_are_reaped_after_linger() {
    let server = Server::start(ServerConfig {
        detach_linger: Duration::from_millis(50),
        ..test_cfg()
    })
    .unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 1, "linger")).unwrap();
    let hs = read_handshake_reply(&mut s).unwrap();
    assert!(hs.accepted);
    s.shutdown(std::net::Shutdown::Both).unwrap();
    drop(s);
    // Give the reader time to detach and the reaper (period = linger/2)
    // time to sweep.
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(server.active_sessions(), 0, "reaper freed the detached slot");
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut s,
        &Handshake::v2("synthetic", 1, "linger")
            .with_resume(Resume { session_id: hs.session_id, token: hs.token, last_ack: 0 }),
    )
    .unwrap();
    let reply = read_handshake_reply(&mut s).unwrap();
    assert!(!reply.accepted);
    assert!(reply.message.contains("unknown session"), "{}", reply.message);
    drop(s);
    let metrics = server.shutdown();
    assert_eq!(metrics.get("sessions_reaped").unwrap().int().unwrap(), 1);
}

// ---------------------------------------------------------------------
// Protocol-v3 wire-codec negotiation and interop (PR 5).
// ---------------------------------------------------------------------

/// New v3 clients at every wire dtype against the new server: all
/// responses byte-verified, and the server's wire counters show the
/// compression the codec promises (~4x at int8 for the request-heavy
/// direction).
#[test]
fn wire_codec_negotiation_end_to_end() {
    use edge_prune::runtime::wire::WireDtype;
    let server = Server::start(test_cfg()).unwrap();
    for (wire, min_ratio) in
        [(WireDtype::F16, 1.4), (WireDtype::I8, 1.4), (WireDtype::SparseI8, 3.0)]
    {
        let report = run_loadgen(&LoadgenConfig {
            addr: server.addr().to_string(),
            clients: 2,
            requests: 20,
            pp: 3,
            wire,
            ..LoadgenConfig::default()
        })
        .unwrap();
        assert_eq!(report.ok, 40, "{wire:?}: {}", report.summary());
        assert_eq!(report.errors, 0, "{wire:?}");
        assert_eq!(report.lost(), 0, "{wire:?}");
        let ratio = report.wire.compression_ratio();
        assert!(ratio > min_ratio, "{wire:?} client-side ratio {ratio}");
        assert!(report.summary().contains("vs f32"), "summary reports the wire gauge");
        if wire == WireDtype::SparseI8 {
            assert!(
                report.wire.achieved_sparsity() > 0.5,
                "sparse wave sparsity {}",
                report.wire.achieved_sparsity()
            );
            assert!(report.summary().contains("sparsity"), "summary reports the sparsity row");
        }
    }
    let metrics = server.shutdown();
    // Server-side counters saw coded requests too.
    let wire = metrics.get("wire").unwrap();
    assert!(wire.get("bytes_rx").unwrap().int().unwrap() > 0);
    let ratio = wire.get("compression_ratio").unwrap().num().unwrap();
    assert!(ratio > 1.5, "server-side ratio {ratio}");
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// int8 wire moves >= 3.5x fewer request bytes than f32 at the default
/// partition point (the acceptance criterion, measured on live client
/// tallies rather than the analytic sizes).
#[test]
fn i8_wire_cuts_bytes_per_inference() {
    use edge_prune::runtime::wire::WireDtype;
    use std::sync::atomic::Ordering;
    let server = Server::start(test_cfg()).unwrap();
    let run = |wire| {
        run_loadgen(&LoadgenConfig {
            addr: server.addr().to_string(),
            clients: 1,
            requests: 10,
            pp: 3,
            wire,
            ..LoadgenConfig::default()
        })
        .unwrap()
    };
    let f32_report = run(WireDtype::F32);
    let i8_report = run(WireDtype::I8);
    assert_eq!(f32_report.ok, 10);
    assert_eq!(i8_report.ok, 10);
    let f32_tx = f32_report.wire.bytes_tx.load(Ordering::Relaxed);
    let i8_tx = i8_report.wire.bytes_tx.load(Ordering::Relaxed);
    assert!(
        (f32_tx as f64) / (i8_tx as f64) >= 3.5,
        "request bytes f32 {f32_tx} vs i8 {i8_tx}"
    );
    server.shutdown();
}

/// A server with the codec disabled (the stand-in for a pre-v3 server
/// config) downgrades an i8-requesting client to raw f32 frames with no
/// semantic change.
#[test]
fn codec_disabled_server_downgrades_to_f32() {
    use edge_prune::runtime::wire::WireDtype;
    use std::sync::atomic::Ordering;
    let server = Server::start(ServerConfig { wire_caps: 0, ..test_cfg() }).unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 2,
        requests: 15,
        pp: 2,
        wire: WireDtype::I8,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 30, "{}", report.summary());
    assert_eq!(report.errors, 0);
    assert_eq!(report.lost(), 0);
    // Everything moved as raw f32: the ratio gauge reads ~1.
    let ratio = report.wire.compression_ratio();
    assert!((ratio - 1.0).abs() < 1e-9, "downgraded session ratio {ratio}");
    assert!(report.wire.bytes_tx.load(Ordering::Relaxed) > 0);
    server.shutdown();
}

/// Old-client interop: a raw protocol-v2 exchange (no capability byte,
/// no codec bytes in the reply) against the new server is byte-for-byte
/// the legacy protocol and serves f32 frames.
#[test]
fn v2_client_interop_against_v3_server() {
    let server = Server::start(test_cfg()).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "old-client")).unwrap();
    let reply = read_handshake_reply(&mut s).unwrap();
    assert!(reply.accepted);
    assert_eq!(reply.codec, None, "v2 reply carries no codec bytes");
    let input = make_input(123);
    write_request(&mut s, 1, &client_prepare(&input, 2)).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.status, RespStatus::Ok);
    assert_eq!(resp.body, expected_digest(&input), "legacy f32 digest");
    write_frame(&mut s, 2, ReqKind::Bye, &[]).unwrap();
    drop(s);
    server.shutdown();
}

/// New-client fallback: against an old server that drops unknown
/// protocol versions replyless, `connect_client` transparently retries
/// at v2 and the session runs raw f32 — no semantic change.
#[test]
fn new_client_falls_back_to_v2_against_old_server() {
    use edge_prune::compiler::PlanKey;
    use edge_prune::runtime::wire::{SessionCodec, WireDtype};
    use edge_prune::server::model::{compile_server_plan, EngineShard, MODEL_NAME};
    use edge_prune::server::protocol::{
        self, connect_client, read_handshake, write_handshake_reply, HandshakeReply, Response,
    };
    use std::io::Read;
    use std::sync::Arc;

    // Stub "old" server: rejects any version != 2 by dropping the
    // connection after the 8-byte head (what the pre-v3 read_handshake
    // did), then speaks plain v2 for the retry.
    let listener = edge_prune::runtime::net::bind_local(0).unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stub = std::thread::spawn(move || {
        // Connection 1: the client's v3 attempt.
        let (mut c1, _) = listener.accept().unwrap();
        let mut head = [0u8; 8];
        c1.read_exact(&mut head).unwrap();
        let version = u16::from_le_bytes(head[4..6].try_into().unwrap());
        assert_eq!(version, 3, "client leads with v3");
        drop(c1); // replyless close, as the old server did
        // Connection 2: the v2 retry gets a real (old-style) session.
        let (mut c2, _) = listener.accept().unwrap();
        let hs = read_handshake(&mut c2).unwrap();
        assert_eq!(hs.version, 2);
        assert_eq!(hs.wire_caps, 0);
        write_handshake_reply(
            &mut c2,
            &HandshakeReply {
                accepted: true,
                resumed: false,
                session_id: 1,
                token: 42,
                codec: None,
                trace: false,
                migrate: false,
                deadline: false,
                message: String::new(),
            },
        )
        .unwrap();
        let plan = Arc::new(compile_server_plan(&PlanKey::new(MODEL_NAME, hs.pp)).unwrap());
        let mut shard = EngineShard::new(plan);
        loop {
            match protocol::read_frame(&mut c2) {
                Ok(Some(f)) if f.kind == ReqKind::Infer => {
                    let body = shard.infer(&f.payload).unwrap();
                    protocol::write_response(&mut c2, &Response::ok(f.seq, body)).unwrap();
                }
                _ => break,
            }
        }
    });

    let hello = Handshake::v3("synthetic", 2, "new-client", WireDtype::I8.caps());
    let (mut s, reply, codec) =
        connect_client(&addr, &hello, Some(Duration::from_secs(5))).unwrap();
    assert!(reply.accepted);
    assert_eq!(codec, SessionCodec::f32(), "fallback session runs the legacy contract");
    let input = make_input(7);
    write_request(&mut s, 1, &client_prepare(&input, 2)).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.body, expected_digest(&input));
    write_frame(&mut s, 2, ReqKind::Bye, &[]).unwrap();
    drop(s);
    stub.join().unwrap();
}

/// Mixed-precision chaos (the PR-2 replay harness, quantized): i8-wire
/// and f32-wire resilient clients hammer one server while killing their
/// own links; every frame completes and verifies, remote or local.
#[test]
fn mixed_precision_chaos_loses_nothing() {
    use edge_prune::runtime::wire::WireDtype;
    let server = Server::start(test_cfg()).unwrap();
    let addr = server.addr().to_string();
    let addr2 = addr.clone();
    let quant = std::thread::spawn(move || {
        run_loadgen(&LoadgenConfig {
            addr: addr2,
            clients: 2,
            requests: 20,
            pp: 2,
            chaos_kill_every: 4,
            wire: WireDtype::I8,
            seed: 31,
            ..LoadgenConfig::default()
        })
    });
    let plain = run_loadgen(&LoadgenConfig {
        addr,
        clients: 2,
        requests: 20,
        pp: 3,
        chaos_kill_every: 5,
        seed: 32,
        ..LoadgenConfig::default()
    })
    .unwrap();
    let quant = quant.join().unwrap().unwrap();
    for (name, report) in [("i8 chaos", &quant), ("f32 chaos", &plain)] {
        assert_eq!(report.ok, 40, "{name}: {}", report.summary());
        assert_eq!(report.errors, 0, "{name}");
        assert_eq!(report.lost(), 0, "{name}");
        assert!((report.service_availability() - 1.0).abs() < 1e-12, "{name}");
        assert!(report.reconnects >= 1, "{name}");
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
    assert!(metrics.get("sessions_resumed").unwrap().int().unwrap() >= 1);
}

/// A v2 client cannot attach to a non-f32-precision server: its reply
/// has no precision byte, so every digest would silently mismatch —
/// the handshake is rejected with an explicit reason instead.
#[test]
fn v2_client_rejected_by_int8_precision_server() {
    use edge_prune::runtime::wire::Precision;
    let server = Server::start(ServerConfig { precision: Precision::Int8, ..test_cfg() }).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "old-client")).unwrap();
    let reply = read_handshake_reply(&mut s).unwrap();
    assert!(!reply.accepted);
    assert!(reply.message.contains("precision"), "{}", reply.message);
    drop(s);
    server.shutdown();
}

/// An int8-precision server with v3 clients: the reply's precision byte
/// makes both sides run the quantized stage chain, so responses stay
/// byte-verifiable end to end (including across a chaos reconnect).
#[test]
fn int8_precision_server_serves_verified_responses() {
    use edge_prune::runtime::wire::{Precision, WireDtype};
    let server = Server::start(ServerConfig { precision: Precision::Int8, ..test_cfg() }).unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 2,
        requests: 15,
        pp: 2,
        wire: WireDtype::I8,
        chaos_kill_every: 6,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 30, "{}", report.summary());
    assert_eq!(report.errors, 0);
    assert_eq!(report.lost(), 0);
    let metrics = server.shutdown();
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// Tracing is process-global state (one recorder registry, one enable
/// flag), so every test that flips it serializes here and restores the
/// disabled default before releasing the lock.
fn trace_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Tentpole acceptance: one traced inference yields a single trace whose
/// spans cover both sides of the wire — the client's request tree and
/// the server's reactor/dispatch/worker/kernel tree all share the
/// trace id the client minted, stitched by the 12-byte wire context.
#[test]
fn traced_inference_joins_client_and_server_spans() {
    use edge_prune::runtime::trace::{self, Stage};
    if !cfg!(feature = "trace") {
        return;
    }
    let _serial = trace_lock();
    let server = Server::start(ServerConfig { trace: true, ..test_cfg() }).unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 2,
        requests: 8,
        pp: 3,
        trace: true,
        ..LoadgenConfig::default()
    })
    .unwrap();
    server.shutdown();
    trace::set_enabled(false);
    let spans = trace::drain();

    assert_eq!(report.ok, 16, "{}", report.summary());
    assert_eq!(report.errors, 0);
    assert_eq!(report.lost(), 0);
    assert_eq!(report.traced, 16, "sample 1 traces every request");

    // Pick one traced request and reassemble its tree.
    let root = spans.iter().find(|s| s.stage == Stage::Request).expect("a client root span");
    let tid = root.trace_id;
    let count =
        |stage: Stage| spans.iter().filter(|s| s.trace_id == tid && s.stage == stage).count();
    for stage in [Stage::ClientEncode, Stage::ClientSend, Stage::ClientWait, Stage::ClientDecode] {
        assert_eq!(count(stage), 1, "{stage:?} under trace {tid:#x}");
    }
    // Server-side spans joined the same trace via the wire context.
    for stage in [
        Stage::ReactorRead,
        Stage::BatchLinger,
        Stage::WorkerQueue,
        Stage::Infer,
        Stage::RespEncode,
    ] {
        assert_eq!(count(stage), 1, "{stage:?} under trace {tid:#x}");
    }

    // Nesting: client stages hang off the root; the server's reactor
    // read hangs off the root too (the wire context carries the root's
    // span id as the remote parent); per-layer kernels nest under the
    // worker's infer span.
    let find = |stage: Stage| {
        spans.iter().find(|s| s.trace_id == tid && s.stage == stage).unwrap()
    };
    let enc = find(Stage::ClientEncode);
    assert_eq!(enc.parent, root.span_id);
    assert_eq!(find(Stage::ReactorRead).parent, root.span_id);
    let infer = find(Stage::Infer);
    let kernels = spans
        .iter()
        .filter(|s| s.trace_id == tid && s.stage == Stage::Kernel && s.parent == infer.span_id)
        .count();
    assert!(kernels >= 1, "per-layer kernel spans under the infer span");

    // Ordering: a child's window sits inside its parent's (same wall
    // clock, child guard drops first).
    assert!(enc.start_us >= root.start_us);
    assert!(enc.start_us + enc.dur_us <= root.start_us + root.dur_us);
    // One process, one clock: the server's infer starts inside the
    // client's request window.
    assert!(infer.start_us >= root.start_us);
    assert!(infer.start_us <= root.start_us + root.dur_us);
}

/// Downgrade: a trace-capable client against a server without `--trace`
/// gets `trace: false` in the reply and silently sends plain infer
/// frames — zero traced requests, zero behavioral change.
#[test]
fn trace_capable_client_downgrades_against_untraced_server() {
    use edge_prune::runtime::trace;
    let _serial = trace_lock();
    let server = Server::start(test_cfg()).unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 2,
        requests: 8,
        pp: 2,
        trace: true,
        ..LoadgenConfig::default()
    })
    .unwrap();
    server.shutdown();
    trace::set_enabled(false);
    let _ = trace::drain();
    assert_eq!(report.ok, 16, "{}", report.summary());
    assert_eq!(report.errors, 0);
    assert_eq!(report.traced, 0, "server without --trace never sees traced frames");
}

/// A v2 client against a traced server: the legacy reply has no trace
/// capability bit and the session serves verified plain frames.
#[test]
fn v2_client_against_traced_server_speaks_plain_protocol() {
    use edge_prune::runtime::trace;
    let _serial = trace_lock();
    let server = Server::start(ServerConfig { trace: true, ..test_cfg() }).unwrap();
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "old-client")).unwrap();
    let reply = read_handshake_reply(&mut s).unwrap();
    assert!(reply.accepted);
    assert!(!reply.trace, "v2 reply carries no trace capability");
    let input = make_input(9);
    write_request(&mut s, 1, &client_prepare(&input, 2)).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.status, RespStatus::Ok);
    assert_eq!(resp.body, expected_digest(&input));
    write_frame(&mut s, 2, ReqKind::Bye, &[]).unwrap();
    drop(s);
    server.shutdown();
    trace::set_enabled(false);
    let _ = trace::drain();
}

/// The `--metrics-addr` scrape endpoint: one raw-TCP connect returns one
/// JSON snapshot carrying live counters plus the drained trace spans,
/// and a drained span never reappears on the next scrape.
#[test]
fn metrics_endpoint_scrape_returns_snapshot_with_trace() {
    use edge_prune::runtime::trace::{self, Stage};
    use edge_prune::util::json::Json;
    use std::io::Read as _;
    if !cfg!(feature = "trace") {
        return;
    }
    let _serial = trace_lock();
    let server = Server::start(ServerConfig {
        trace: true,
        metrics_addr: Some("127.0.0.1:0".to_string()),
        ..test_cfg()
    })
    .unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 2,
        requests: 4,
        pp: 2,
        trace: true,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 8, "{}", report.summary());

    let scrape = |ep: std::net::SocketAddr| -> Json {
        let mut sock = TcpStream::connect(ep).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut body = String::new();
        sock.read_to_string(&mut body).unwrap();
        Json::parse(&body).unwrap()
    };
    let ep = server.metrics_endpoint_addr().expect("endpoint bound");
    let snap = scrape(ep);
    assert_eq!(snap.get("requests_completed").unwrap().int().unwrap(), 8);
    let tr = snap.get("trace").unwrap();
    assert!(matches!(tr.get("enabled").unwrap(), Json::Bool(true)));
    let rows = tr.get("spans").unwrap().arr().unwrap();
    assert!(!rows.is_empty(), "scrape drains recorded spans");
    let parsed: Vec<_> =
        rows.iter().map(|r| trace::span_from_json(r).unwrap()).collect();
    assert!(parsed.iter().any(|s| s.stage == Stage::Request), "client root span in scrape");
    assert!(parsed.iter().any(|s| s.stage == Stage::Infer), "server infer span in scrape");

    // Spans are handed out exactly once: the drained client roots are
    // gone from the second snapshot (trace tests are serialized, so no
    // one else can mint Request spans meanwhile).
    let snap2 = scrape(ep);
    let rows2 = snap2.get("trace").unwrap().get("spans").unwrap().arr().unwrap();
    for r in rows2 {
        let s = trace::span_from_json(r).unwrap();
        assert!(s.stage != Stage::Request, "drained span reappeared in second scrape");
    }

    server.shutdown();
    trace::set_enabled(false);
    let _ = trace::drain();
}

// ---------------------------------------------------------------------
// Thread-per-core shards: cross-shard RECONNECT and chaos (PR 7).
// ---------------------------------------------------------------------

/// The PR-2 replay contract must survive crossing cores: a session born
/// on shard 0 is killed mid-stream and its RECONNECT lands on shard 1
/// (round-robin accept makes the placement deterministic).  The resumed
/// shard replays from the ring, answers a client re-send from the ring,
/// and runs fresh work — with zero lost and zero duplicated executions
/// across the two shards' independent queues and worker sets.
#[test]
fn cross_shard_reconnect_replays_exactly_once() {
    let server = Server::start(ServerConfig { cores: 2, accept_rr: true, ..test_cfg() }).unwrap();
    assert_eq!(server.cores(), 2);

    // Connection #0 -> shard 0: three completed inferences.
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(&mut s, &Handshake::v2("synthetic", 2, "xshard")).unwrap();
    let hs = read_handshake_reply(&mut s).unwrap();
    assert!(hs.accepted && !hs.resumed);
    for seq in [1u64, 2, 3] {
        let input = make_input(seq);
        write_request(&mut s, seq, &client_prepare(&input, 2)).unwrap();
        let resp = read_response(&mut s).unwrap().unwrap();
        assert_eq!(resp.req_id, seq);
        assert_eq!(resp.body, expected_digest(&input));
    }

    // Abrupt cut — the session detaches on shard 0, state retained.
    s.shutdown(std::net::Shutdown::Both).unwrap();
    drop(s);
    std::thread::sleep(Duration::from_millis(50));

    // Connection #1 -> shard 1: RECONNECT acknowledging only seq 1.
    // The *other* shard must find the session, replay 2 and 3 in order,
    // and take over the stream.
    let mut s = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut s,
        &Handshake::v2("synthetic", 2, "xshard")
            .with_resume(Resume { session_id: hs.session_id, token: hs.token, last_ack: 1 }),
    )
    .unwrap();
    let hs2 = read_handshake_reply(&mut s).unwrap();
    assert!(hs2.accepted && hs2.resumed, "cross-shard resume refused: {}", hs2.message);
    for seq in [2u64, 3] {
        let replayed = read_response(&mut s).unwrap().unwrap();
        assert_eq!(replayed.req_id, seq, "attach replay order");
        assert_eq!(replayed.body, expected_digest(&make_input(seq)));
    }
    // A client-side re-send of seq 3 is answered from the ring by the
    // new home shard, not re-executed.
    write_request(&mut s, 3, &client_prepare(&make_input(3), 2)).unwrap();
    let dup = read_response(&mut s).unwrap().unwrap();
    assert_eq!(dup.req_id, 3);
    assert_eq!(dup.body, expected_digest(&make_input(3)));
    // Fresh work executes on shard 1's own queue and workers.
    for seq in [4u64, 5] {
        let input = make_input(seq);
        write_request(&mut s, seq, &client_prepare(&input, 2)).unwrap();
        let resp = read_response(&mut s).unwrap().unwrap();
        assert_eq!(resp.req_id, seq);
        assert_eq!(resp.body, expected_digest(&input));
    }
    write_frame(&mut s, 6, ReqKind::Bye, &[]).unwrap();
    drop(s);

    // Per-shard ledger: 3 executions stayed on shard 0, 2 ran on shard
    // 1, nothing executed twice.
    let loads = server.shard_loads();
    assert_eq!(loads.len(), 2);
    assert_eq!(loads[0].1, 3, "shard 0 executed the pre-cut inferences");
    assert_eq!(loads[1].1, 2, "shard 1 executed only the fresh work");
    assert_eq!(loads[0].0, 1, "the session was admitted on shard 0");

    let metrics = server.shutdown();
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 5);
    assert_eq!(metrics.get("sessions_detached").unwrap().int().unwrap(), 1);
    assert_eq!(metrics.get("sessions_resumed").unwrap().int().unwrap(), 1);
    // 2 from the attach replay + 1 answering the client re-send.
    assert_eq!(metrics.get("responses_replayed").unwrap().int().unwrap(), 3);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
    assert_eq!(metrics.get("duplicate_requests").unwrap().int().unwrap(), 0);
}

/// Chaos across shards: resilient clients kill their own links every few
/// requests against a 2-core server with round-robin accept, so nearly
/// every RECONNECT lands on the other shard.  Zero lost inferences, and
/// the merged execution count proves no request ran twice.
#[test]
fn cross_shard_chaos_loadgen_loses_nothing() {
    let server = Server::start(ServerConfig { cores: 2, accept_rr: true, ..test_cfg() }).unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 4,
        requests: 20,
        pp: 2,
        chaos_kill_every: 4,
        seed: 77,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 80, "{}", report.summary());
    assert_eq!(report.lost(), 0);
    assert_eq!(report.errors, 0);
    assert!((report.service_availability() - 1.0).abs() < 1e-12);
    assert!(report.reconnects >= 12, "4 kills per client, got {}", report.reconnects);
    assert!(report.sessions_resumed >= 1);

    // Both shards did real work (the round-robin spread guarantees it).
    let loads = server.shard_loads();
    assert!(loads.iter().all(|&(_, completed)| completed > 0), "idle shard: {loads:?}");

    let metrics = server.shutdown();
    // Exactly-once across shards: every remotely-served inference
    // executed exactly once, no matter how many times its link died
    // (local fallback serves a frame without the server seeing it, so
    // subtract those).
    assert_eq!(
        metrics.get("requests_completed").unwrap().int().unwrap(),
        80 - report.served_local as i64
    );
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
    assert!(metrics.get("sessions_resumed").unwrap().int().unwrap() >= 1);
    assert_eq!(metrics.get("cores").unwrap().int().unwrap(), 2);
}

/// The session wave holds its sessions at int8 wire too (the reactor's
/// frame sizes change, nothing else).
#[test]
fn session_wave_runs_at_i8_wire() {
    use edge_prune::runtime::wire::WireDtype;
    ensure_fd_headroom(256);
    let server = Server::start(ServerConfig { max_sessions: 80, ..test_cfg() }).unwrap();
    let report = run_session_wave(&WaveConfig {
        addr: server.addr().to_string(),
        sessions: 64,
        rounds: 2,
        pp: 2,
        wire: WireDtype::I8,
        ..WaveConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 128);
    assert_eq!(report.errors, 0);
    server.shutdown();
}

// ---------------------------------------------------------------------
// Sparse activation wire (ISSUE 8): session-sticky dtype across
// RECONNECT, and the sparse wave against a sharded server.
// ---------------------------------------------------------------------

/// The wire dtype is a session property fixed at admission: a RECONNECT
/// whose handshake advertises *different* capabilities must not
/// renegotiate — the attach replay, a ring-answered client re-send, and
/// fresh work all run at the dtype the session was admitted with.  A v2
/// resume of a sparse session is refused outright: the legacy reply
/// cannot tell the client what dtype the replay ring speaks.
#[test]
fn reconnect_keeps_the_admission_wire_dtype_for_replay() {
    use edge_prune::runtime::wire::WireDtype;
    use edge_prune::server::model::{client_prepare_codec, expected_digest_codec};
    use edge_prune::server::protocol::connect_client;

    let server = Server::start(test_cfg()).unwrap();
    let addr = server.addr().to_string();

    // Fresh v3 session advertising sparse: negotiation lands on it.
    let hello = Handshake::v3("synthetic", 2, "sticky", WireDtype::SparseI8.caps());
    let (mut s, reply, codec) =
        connect_client(&addr, &hello, Some(Duration::from_secs(5))).unwrap();
    assert!(reply.accepted && !reply.resumed);
    assert_eq!(codec.wire, WireDtype::SparseI8);

    // Two completed inferences at the sparse codec.
    for seq in [1u64, 2] {
        let input = make_input(seq);
        write_request(&mut s, seq, &client_prepare_codec(&input, 2, codec)).unwrap();
        let resp = read_response(&mut s).unwrap().unwrap();
        assert_eq!(resp.req_id, seq);
        assert_eq!(resp.body, expected_digest_codec(&input, 2, codec), "seq {seq}");
    }

    // Abrupt cut, then a RECONNECT advertising only i8 (a client
    // restarted with a narrower flag): the session must stay sparse.
    s.shutdown(std::net::Shutdown::Both).unwrap();
    drop(s);
    std::thread::sleep(Duration::from_millis(50));
    let again = Handshake::v3("synthetic", 2, "sticky", WireDtype::I8.caps()).with_resume(Resume {
        session_id: reply.session_id,
        token: reply.token,
        last_ack: 1,
    });
    let (mut s, reply2, codec2) =
        connect_client(&addr, &again, Some(Duration::from_secs(5))).unwrap();
    assert!(reply2.accepted && reply2.resumed, "{}", reply2.message);
    assert_eq!(codec2.wire, WireDtype::SparseI8, "resume must keep the admission dtype");

    // The attach replay of seq 2 comes from the ring and still verifies
    // against the sparse-codec ground truth.
    let replayed = read_response(&mut s).unwrap().unwrap();
    assert_eq!(replayed.req_id, 2);
    assert_eq!(replayed.body, expected_digest_codec(&make_input(2), 2, codec));

    // A client-side re-send of seq 2 — encoded at the session dtype —
    // is answered from the ring, not re-executed.
    write_request(&mut s, 2, &client_prepare_codec(&make_input(2), 2, codec)).unwrap();
    let dup = read_response(&mut s).unwrap().unwrap();
    assert_eq!(dup.req_id, 2);
    assert_eq!(dup.body, expected_digest_codec(&make_input(2), 2, codec));

    // Fresh work on the resumed session runs at sparse too.
    let input = make_input(3);
    write_request(&mut s, 3, &client_prepare_codec(&input, 2, codec)).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.req_id, 3);
    assert_eq!(resp.body, expected_digest_codec(&input, 2, codec));

    // Cut again; a v2 resume of the sparse session is refused.
    s.shutdown(std::net::Shutdown::Both).unwrap();
    drop(s);
    std::thread::sleep(Duration::from_millis(50));
    let mut old = TcpStream::connect(server.addr()).unwrap();
    write_handshake(
        &mut old,
        &Handshake::v2("synthetic", 2, "sticky").with_resume(Resume {
            session_id: reply.session_id,
            token: reply.token,
            last_ack: 3,
        }),
    )
    .unwrap();
    let refused = read_handshake_reply(&mut old).unwrap();
    assert!(!refused.accepted, "v2 resumed a sparse session");
    assert!(refused.message.contains("wire"), "{}", refused.message);
    drop(old);

    let metrics = server.shutdown();
    // Exactly-once held across the dtype-preserving resume: 3 distinct
    // inferences despite seq 2 being delivered three times.
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 3);
    assert_eq!(metrics.get("sessions_resumed").unwrap().int().unwrap(), 1);
    assert!(metrics.get("responses_replayed").unwrap().int().unwrap() >= 2);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// Sparse chaos (the PR-2 replay harness at the new dtype): resilient
/// sparse-wire clients hammer a 2-core round-robin server while killing
/// their own links, so RECONNECTs cross shards with the sticky dtype.
/// Zero lost, every response verified.
#[test]
fn sparse_chaos_across_shards_loses_nothing() {
    use edge_prune::runtime::wire::WireDtype;
    let server = Server::start(ServerConfig { cores: 2, accept_rr: true, ..test_cfg() }).unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 3,
        requests: 20,
        pp: 2,
        chaos_kill_every: 4,
        wire: WireDtype::SparseI8,
        seed: 91,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 60, "{}", report.summary());
    assert_eq!(report.errors, 0);
    assert_eq!(report.lost(), 0);
    assert!((report.service_availability() - 1.0).abs() < 1e-12);
    assert!(report.reconnects >= 1);
    let metrics = server.shutdown();
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
    assert!(metrics.get("sessions_resumed").unwrap().int().unwrap() >= 1);
}

/// The session wave holds at the sparse wire dtype too (what the CI
/// 64-session sparse wave runs against a 2-core server).
#[test]
fn session_wave_runs_at_sparse_wire() {
    use edge_prune::runtime::wire::WireDtype;
    ensure_fd_headroom(256);
    let server = Server::start(ServerConfig { max_sessions: 80, ..test_cfg() }).unwrap();
    let report = run_session_wave(&WaveConfig {
        addr: server.addr().to_string(),
        sessions: 64,
        rounds: 2,
        pp: 2,
        wire: WireDtype::SparseI8,
        ..WaveConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 128);
    assert_eq!(report.errors, 0);
    server.shutdown();
}

/// Live migration (the fleet tentpole, client-initiated): an Export
/// moves a sparse-wire session from server A to server B mid-stream.
/// The replay ring, epoch, and negotiated dtype survive the move, the
/// client never restarts, and the merged ledgers prove exactly-once.
#[test]
fn live_migration_moves_session_between_servers() {
    use edge_prune::runtime::wire::WireDtype;
    use edge_prune::server::model::expected_digest_codec;
    let server_a = Server::start(test_cfg()).unwrap();
    let server_b = Server::start(test_cfg()).unwrap();
    let addr_b = server_b.addr().to_string();

    let mut fc = FailoverClient::new(FailoverConfig {
        addr: server_a.addr().to_string(),
        pp: 2,
        client_id: "mover".into(),
        wire: WireDtype::SparseI8,
        max_attempts: 3,
        reconnect_backoff: Duration::from_millis(1),
        ..FailoverConfig::default()
    });
    for i in 0..5u64 {
        let input = make_input(i);
        let (body, served) = fc.infer(&input).unwrap();
        assert!(!served.is_local(), "frame {i} before migration");
        assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
    }
    assert_eq!(fc.codec().wire, WireDtype::SparseI8, "session negotiated sparse");

    fc.migrate_to(&addr_b).unwrap();
    assert_eq!(fc.addr(), addr_b, "client redirected by the hint");
    assert_eq!(fc.stats().migrations_followed, 1);

    // The same client keeps inferring: the next exchange resumes on B
    // with the peer-minted credentials, still at the sparse dtype.
    for i in 5..10u64 {
        let input = make_input(i);
        let (body, served) = fc.infer(&input).unwrap();
        assert!(!served.is_local(), "frame {i} after migration");
        assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
    }
    assert_eq!(fc.codec().wire, WireDtype::SparseI8, "dtype survived the move");
    fc.finish();
    let stats = fc.stats();
    assert_eq!(stats.completed, 10, "zero loss through the migration");
    assert_eq!(stats.served_remote, 10);

    let ma = server_a.shutdown();
    let mb = server_b.shutdown();
    assert_eq!(ma.get("sessions_migrated_out").unwrap().int().unwrap(), 1);
    assert_eq!(mb.get("sessions_migrated_in").unwrap().int().unwrap(), 1);
    // The post-migrate RECONNECT claims the imported slot: B counts it
    // as a placement rebalance (the fleet actually moved this session).
    assert_eq!(mb.get("placement_rebalances").unwrap().int().unwrap(), 1);
    // Exactly-once across the pair: every frame executed on exactly one
    // server, and the halves land where the timeline says they should.
    let done_a = ma.get("requests_completed").unwrap().int().unwrap();
    let done_b = mb.get("requests_completed").unwrap().int().unwrap();
    assert_eq!(done_a, 5, "pre-migration frames ran on A");
    assert_eq!(done_a + done_b, 10, "a={done_a} b={done_b}");
    assert_eq!(ma.get("request_errors").unwrap().int().unwrap(), 0);
    assert_eq!(mb.get("request_errors").unwrap().int().unwrap(), 0);
}

/// Signal-driven rolling drain: a real SIGTERM (raised in-process
/// through the raw handler `serve --drain-on SIGTERM` installs) latches
/// the flag, the drain quiesces the server and hands its session to a
/// fleet peer, and the attached client follows the unsolicited MIGRATE
/// hint — zero inferences lost end to end.
#[test]
fn signal_drain_loses_zero_inferences() {
    use edge_prune::runtime::wire::WireDtype;
    use edge_prune::server::fleet;
    use edge_prune::server::model::expected_digest_codec;
    let server_a = Server::start(test_cfg()).unwrap();
    let server_b = Server::start(test_cfg()).unwrap();
    let addr_b = server_b.addr().to_string();

    let mut fc = FailoverClient::new(FailoverConfig {
        addr: server_a.addr().to_string(),
        pp: 2,
        client_id: "drainee".into(),
        wire: WireDtype::I8,
        max_attempts: 3,
        reconnect_backoff: Duration::from_millis(1),
        ..FailoverConfig::default()
    });
    for i in 0..5u64 {
        let input = make_input(i);
        let (body, _) = fc.infer(&input).unwrap();
        assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
    }

    // What the serve loop does on SIGTERM: the handler latches, the
    // poll observes the latch, the drain runs from thread context.
    fleet::raise_drain_signal();
    assert!(fleet::drain_requested(), "SIGTERM latched the drain flag");
    let drained = server_a.drain_to(Some(&addr_b));
    fleet::clear_drain_request();
    assert!(server_a.is_draining(), "drained server refuses fresh admissions");
    assert_eq!(drained.get("sessions_migrated_out").unwrap().int().unwrap(), 1);
    assert!(drained.get("drain_duration_ms").unwrap().int().unwrap() >= 0);

    // The client sat idle through the drain; its next exchange reads
    // the hint (then the prompt EOF from the retired attachment),
    // redials B with the peer-minted credentials, and loses nothing.
    for i in 5..10u64 {
        let input = make_input(i);
        let (body, served) = fc.infer(&input).unwrap();
        assert!(!served.is_local(), "frame {i} after the drain");
        assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
    }
    fc.finish();
    let stats = fc.stats();
    assert_eq!(stats.completed, 10, "zero loss through the signal drain");
    assert_eq!(stats.migrations_followed, 1);

    let ma = server_a.shutdown();
    let mb = server_b.shutdown();
    assert_eq!(ma.get("sessions_migrated_out").unwrap().int().unwrap(), 1);
    assert_eq!(mb.get("sessions_migrated_in").unwrap().int().unwrap(), 1);
    let done = ma.get("requests_completed").unwrap().int().unwrap()
        + mb.get("requests_completed").unwrap().int().unwrap();
    assert_eq!(done, 10, "exactly-once across the drained pair");
}

/// Fleet chaos: loadgen places sessions by rendezvous hashing over a
/// 3-server manifest while one server is hard-killed and a second is
/// rolling-drained into the third mid-wave.  Zero inferences lost, and
/// the merged server ledgers stay within the exactly-once band (a
/// dropped-response retry may legitimately execute once per ledger on
/// each side of a failure, never more).
#[test]
fn fleet_survives_kill_and_rolling_drain() {
    use edge_prune::runtime::wire::WireDtype;
    ensure_fd_headroom(256);
    let server_a = Server::start(test_cfg()).unwrap();
    let server_b = Server::start(test_cfg()).unwrap();
    let server_c = Server::start(test_cfg()).unwrap();
    let addr_a = server_a.addr().to_string();
    let addr_b = server_b.addr().to_string();
    let fleet = vec![addr_a.clone(), addr_b.clone(), server_c.addr().to_string()];

    let clients = 6usize;
    let requests = 120u64;
    let cfg = LoadgenConfig {
        addr: addr_a.clone(),
        clients,
        requests,
        pp: 2,
        fleet: fleet.clone(),
        wire: WireDtype::SparseI8,
        // ~2 ms of shaped latency per frame keeps the wave in flight
        // long enough for the kill and the drain to land mid-run.
        link: Some(LinkModel::new("paced", 100.0, 2.0)),
        seed: 4242,
        ..LoadgenConfig::default()
    };
    let wave = std::thread::spawn(move || run_loadgen(&cfg));

    // Hard-kill one member mid-wave; its clients rehome to the
    // rendezvous runner-up (locally-absorbed frames bridge the gap).
    std::thread::sleep(Duration::from_millis(60));
    let mc = server_c.shutdown();
    // Rolling drain of a second member into a survivor; it rejoins the
    // fleet afterwards, as a rolling restart would.
    std::thread::sleep(Duration::from_millis(60));
    let _ = server_a.drain_to(Some(&addr_b));
    server_a.resume_admissions();

    let report = wave.join().unwrap().unwrap();
    let total = (clients as u64) * requests;
    assert_eq!(report.ok, total, "{}", report.summary());
    assert_eq!(report.errors, 0, "{}", report.summary());
    assert_eq!(report.lost(), 0);

    let ma = server_a.shutdown();
    let mb = server_b.shutdown();
    // Merged exactly-once ledger: every completed frame is accounted
    // for by exactly one server execution or one local fallback.  A
    // frame whose response died with the killed/drained server may
    // execute once more on the recovery path — bounded by a couple of
    // in-flight frames per client per disruption, never unbounded.
    let merged = ma.get("requests_completed").unwrap().int().unwrap()
        + mb.get("requests_completed").unwrap().int().unwrap()
        + mc.get("requests_completed").unwrap().int().unwrap()
        + report.served_local as i64;
    assert!(merged >= total as i64, "ledger undercount: {merged} < {total}");
    assert!(
        merged <= (total + 4 * clients as u64) as i64,
        "ledger overcount breaks exactly-once: {merged} vs {total}"
    );
}

/// Deadline propagation end to end: a zero-budget kind-7 frame is
/// refused with an explicit `DEADLINE_EXCEEDED` before any compute, a
/// real budget completes and verifies on the same session, and the
/// server's refusal ledger matches.
#[test]
fn expired_deadline_gets_explicit_refusal_before_compute() {
    use edge_prune::runtime::wire::CAP_DEADLINE;
    use edge_prune::server::protocol::{connect_client, encode_deadline_prefix};
    let server = Server::start(test_cfg()).unwrap();
    let hello = Handshake::v3("synthetic", 2, "deadliner", CAP_DEADLINE);
    let (mut s, reply, _codec) =
        connect_client(&server.addr().to_string(), &hello, Some(Duration::from_secs(5))).unwrap();
    assert!(reply.accepted);
    assert!(reply.deadline, "v3 + both cap bits grants deadlines");

    // Budget 0: expired on arrival, dropped at admission — no worker
    // slot burned, the seq answered explicitly.
    let input = make_input(1);
    let mut framed = encode_deadline_prefix(0, 3).to_vec();
    framed.extend_from_slice(&client_prepare(&input, 2));
    write_frame(&mut s, 1, ReqKind::DeadlineInfer, &framed).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.req_id, 1);
    assert_eq!(resp.status, RespStatus::DeadlineExceeded);

    // A generous budget completes and verifies on the same session.
    let input = make_input(2);
    let mut framed = encode_deadline_prefix(30_000, 3).to_vec();
    framed.extend_from_slice(&client_prepare(&input, 2));
    write_frame(&mut s, 2, ReqKind::DeadlineInfer, &framed).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.status, RespStatus::Ok);
    assert_eq!(resp.body, expected_digest(&input));
    write_frame(&mut s, 3, ReqKind::Bye, &[]).unwrap();
    drop(s);

    let metrics = server.shutdown();
    assert_eq!(metrics.get("deadline_exceeded").unwrap().int().unwrap(), 1);
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), 1);
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// The CAP_DEADLINE downgrade matrix: no grant unless both sides
/// advertise the bit, and a kind-7 frame on an ungranted session is an
/// explicit error response — the session survives (the client may be
/// probing a mixed fleet), unlike a framing violation.
#[test]
fn deadline_downgrade_matrix_is_explicit() {
    use edge_prune::runtime::wire::{WireDtype, CAP_DEADLINE};
    use edge_prune::server::protocol::{connect_client, encode_deadline_prefix};

    // Client without the bit against a capable server.
    let server = Server::start(test_cfg()).unwrap();
    let addr = server.addr().to_string();
    let hello = Handshake::v3("synthetic", 2, "no-bit", WireDtype::F32.caps());
    let (mut s, reply, _) = connect_client(&addr, &hello, Some(Duration::from_secs(5))).unwrap();
    assert!(reply.accepted);
    assert!(!reply.deadline, "grant requires the client bit");
    let mut framed = encode_deadline_prefix(1_000, 0).to_vec();
    framed.extend_from_slice(&client_prepare(&make_input(1), 2));
    write_frame(&mut s, 1, ReqKind::DeadlineInfer, &framed).unwrap();
    let resp = read_response(&mut s).unwrap().unwrap();
    assert_eq!(resp.status, RespStatus::Error);
    assert!(
        String::from_utf8(resp.body).unwrap().contains("CAP_DEADLINE"),
        "refusal names the missing capability"
    );
    // The refused frame did not tear the session down.
    let input = make_input(2);
    write_request(&mut s, 2, &client_prepare(&input, 2)).unwrap();
    assert_eq!(read_response(&mut s).unwrap().unwrap().body, expected_digest(&input));
    write_frame(&mut s, 3, ReqKind::Bye, &[]).unwrap();
    drop(s);
    server.shutdown();

    // Willing client against a capability-stripped server: accepted,
    // but silently downgraded to plain infer semantics.
    let server = Server::start(ServerConfig { wire_caps: 0, ..test_cfg() }).unwrap();
    let hello = Handshake::v3("synthetic", 2, "willing", CAP_DEADLINE);
    let (mut s, reply, _) =
        connect_client(&server.addr().to_string(), &hello, Some(Duration::from_secs(5))).unwrap();
    assert!(reply.accepted);
    assert!(!reply.deadline, "grant requires the server bit");
    write_frame(&mut s, 1, ReqKind::Bye, &[]).unwrap();
    drop(s);
    server.shutdown();
}

/// A kind-7 frame too short to carry its 5-byte deadline prefix is a
/// protocol violation on a granted session: the connection closes
/// cleanly (no panic, no partial parse) and the server keeps serving.
#[test]
fn truncated_deadline_prefix_closes_connection_cleanly() {
    use edge_prune::runtime::wire::CAP_DEADLINE;
    use edge_prune::server::protocol::connect_client;
    let server = Server::start(test_cfg()).unwrap();
    let addr = server.addr().to_string();
    let hello = Handshake::v3("synthetic", 2, "torn-prefix", CAP_DEADLINE);
    let (mut s, reply, _) = connect_client(&addr, &hello, Some(Duration::from_secs(5))).unwrap();
    assert!(reply.accepted && reply.deadline);
    write_frame(&mut s, 1, ReqKind::DeadlineInfer, &[1, 2, 3]).unwrap();
    match read_response(&mut s) {
        Ok(None) | Err(_) => {}
        Ok(Some(resp)) => panic!("expected a close, got a {:?} response", resp.status),
    }
    drop(s);
    // The server survives for the next session.
    let report = run_loadgen(&LoadgenConfig {
        addr,
        clients: 1,
        requests: 5,
        pp: 2,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert_eq!(report.ok, 5, "{}", report.summary());
    server.shutdown();
}

/// The overload acceptance gate: a deadline-carrying wave against a
/// deliberately starved server (one worker, tiny shed bound) sheds work
/// — and every single non-admitted request gets an explicit outcome.
/// Zero lost, and the server's shed ledger matches the clients' exactly
/// (strict loadgen clients never re-offer a shed request).
#[test]
fn overload_wave_sheds_explicitly_with_zero_lost() {
    let server = Server::start(ServerConfig {
        workers: 1,
        max_batch: 2,
        batch_linger: Duration::from_millis(2),
        // Any measured queue wait crosses the bound, so shedding kicks
        // in as soon as requests actually overlap in the queue.
        shed_delay_ms: 0.0005,
        ..test_cfg()
    })
    .unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients: 8,
        requests: 25,
        pp: 2,
        deadline_ms: 30_000,
        priority: 0,
        seed: 7000,
        ..LoadgenConfig::default()
    })
    .unwrap();

    assert_eq!(report.sent, 200, "{}", report.summary());
    assert_eq!(report.errors, 0, "{}", report.summary());
    assert_eq!(report.lost(), 0, "{}", report.summary());
    assert_eq!(
        report.ok + report.rejected + report.shed + report.deadline_exceeded,
        report.sent,
        "every request got an explicit outcome: {}",
        report.summary()
    );
    assert!(report.shed >= 1, "the starved server shed work: {}", report.summary());
    assert!(report.ok >= 1, "admitted work still completed");

    let metrics = server.shutdown();
    assert_eq!(
        metrics.get("requests_shed").unwrap().int().unwrap(),
        report.shed as i64,
        "server and client shed ledgers agree"
    );
    assert_eq!(
        metrics.get("deadline_exceeded").unwrap().int().unwrap(),
        report.deadline_exceeded as i64
    );
    assert_eq!(metrics.get("requests_completed").unwrap().int().unwrap(), report.ok as i64);
    assert!(
        metrics.get("queue_delay_ewma_ms").unwrap().num().unwrap() > 0.0,
        "the queue-wait gauge saw real samples"
    );
    assert_eq!(metrics.get("request_errors").unwrap().int().unwrap(), 0);
}

/// Health-driven rebalancing (the tentpole, server-initiated): a shard
/// hot past its dwell volunteers its most expensive idle session to the
/// least-loaded manifest peer, the attached client follows the
/// unsolicited MIGRATE hint live, and the merged ledgers prove zero
/// loss.
#[test]
fn hot_shard_volunteers_session_to_cold_peer() {
    use edge_prune::server::fleet;
    use edge_prune::server::model::expected_digest_codec;
    let server_b = Server::start(test_cfg()).unwrap();
    let addr_b = server_b.addr().to_string();
    let server_a = Server::start(ServerConfig {
        // "Anything measured counts as hot" posture: at a 0.0 delay
        // bound the first popped batch makes A hot and keeps it hot
        // (the EWMA never decays back to exactly zero), and the dwell
        // is long enough that the move lands while the clients idle.
        rebalance_peers: vec![addr_b.clone()],
        rebalance_hot: Duration::from_millis(150),
        rebalance_cooldown: Duration::from_secs(60),
        ..test_cfg()
    })
    .unwrap();
    let addr_a = server_a.addr().to_string();

    // TWO sessions on A: the volunteer guard (`peer_load + 1 <
    // local_load`) refuses to hand off a server's only session, so a
    // single-session server can never drain itself through its own
    // balancer.
    let mut movers: Vec<FailoverClient> = (0..2)
        .map(|i| {
            FailoverClient::new(FailoverConfig {
                addr: addr_a.clone(),
                pp: 2,
                client_id: format!("hot-{i}"),
                max_attempts: 3,
                reconnect_backoff: Duration::from_millis(1),
                ..FailoverConfig::default()
            })
        })
        .collect();
    for i in 0..5u64 {
        for fc in movers.iter_mut() {
            let input = make_input(i);
            let (body, _) = fc.infer(&input).unwrap();
            assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
        }
    }

    // Wait for the balancer: the dwell elapses, B probes as the cold
    // peer, and exactly one session moves (after which load parity
    // stops further volunteering).
    let mut moved = false;
    for _ in 0..400 {
        if fleet::probe_peer_load(&addr_b, Duration::from_secs(1)).unwrap_or(0) >= 1 {
            moved = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(moved, "rebalancer never volunteered a session to the cold peer");

    // Both clients keep inferring; the redirected one follows the hint.
    for i in 5..10u64 {
        for fc in movers.iter_mut() {
            let input = make_input(i);
            let (body, served) = fc.infer(&input).unwrap();
            assert!(!served.is_local(), "frame {i} stayed remote through the move");
            assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
        }
    }
    let mut followed = 0;
    for mut fc in movers {
        fc.finish();
        let st = fc.stats();
        followed += st.migrations_followed;
        assert_eq!(st.completed, 10, "zero loss through the rebalance");
    }
    assert_eq!(followed, 1, "exactly one session was volunteered");

    let ma = server_a.shutdown();
    let mb = server_b.shutdown();
    assert_eq!(ma.get("sessions_rebalanced").unwrap().int().unwrap(), 1);
    assert_eq!(mb.get("sessions_migrated_in").unwrap().int().unwrap(), 1);
    let done = ma.get("requests_completed").unwrap().int().unwrap()
        + mb.get("requests_completed").unwrap().int().unwrap();
    assert_eq!(done, 20, "exactly-once across the rebalanced pair");
    assert_eq!(ma.get("request_errors").unwrap().int().unwrap(), 0);
    assert_eq!(mb.get("request_errors").unwrap().int().unwrap(), 0);
}

/// `probe_peer_load` reads the live load a peer embeds in its fleet
/// handshake reply, and `volunteer_once` is the rebalancer's
/// deterministic single step — it hands one idle session over without
/// waiting out a dwell (and without the load-parity guard).
#[test]
fn volunteer_once_and_peer_load_probe() {
    use edge_prune::server::fleet;
    use edge_prune::server::model::expected_digest_codec;
    let server_a = Server::start(test_cfg()).unwrap();
    let server_b = Server::start(test_cfg()).unwrap();
    let addr_a = server_a.addr().to_string();
    let addr_b = server_b.addr().to_string();

    assert_eq!(fleet::probe_peer_load(&addr_b, Duration::from_secs(2)).unwrap(), 0);

    let mut fc = FailoverClient::new(FailoverConfig {
        addr: addr_a.clone(),
        pp: 2,
        client_id: "volunteered".into(),
        max_attempts: 3,
        reconnect_backoff: Duration::from_millis(1),
        ..FailoverConfig::default()
    });
    for i in 0..3u64 {
        let input = make_input(i);
        let (body, _) = fc.infer(&input).unwrap();
        assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
    }
    assert_eq!(
        fleet::probe_peer_load(&addr_a, Duration::from_secs(2)).unwrap(),
        1,
        "an attached idle session reads back as load 1"
    );

    let moved_id = server_a.volunteer_once(&addr_b).unwrap();
    assert!(moved_id >= 1, "volunteer returns the exported session id");
    assert_eq!(fleet::probe_peer_load(&addr_b, Duration::from_secs(2)).unwrap(), 1);

    // The client's next exchanges read the hint, redial B with the
    // peer-minted credentials, and lose nothing.
    for i in 3..6u64 {
        let input = make_input(i);
        let (body, served) = fc.infer(&input).unwrap();
        assert!(!served.is_local(), "frame {i} after the volunteer");
        assert_eq!(body, expected_digest_codec(&input, 2, fc.codec()), "frame {i}");
    }
    fc.finish();
    let st = fc.stats();
    assert_eq!(st.completed, 6, "zero loss through the volunteer");
    assert_eq!(st.migrations_followed, 1);

    let ma = server_a.shutdown();
    let mb = server_b.shutdown();
    assert_eq!(ma.get("sessions_rebalanced").unwrap().int().unwrap(), 1);
    assert_eq!(mb.get("sessions_migrated_in").unwrap().int().unwrap(), 1);
}
