//! Property-based tests over framework invariants (using the in-tree
//! `util::prop` harness): random graphs/mappings/workloads must uphold
//! the analyzer's and compiler's contracts.

use edge_prune::compiler::compile;
use edge_prune::dataflow::{AppGraph, RateSpec};
use edge_prune::platform::{Mapping, PlatformGraph};
use edge_prune::runtime::device::DeviceModel;
use edge_prune::runtime::netsim::LinkModel;
use edge_prune::util::prop::forall;
use edge_prune::util::rng::Rng;

/// Random connected DAG with random (consistent-by-construction) rates:
/// a chain with extra forward edges, rates fixed at 1 (homogeneous SDF).
fn random_dag(rng: &mut Rng, size: usize) -> AppGraph {
    let n = size.clamp(2, 12);
    let mut g = AppGraph::new();
    let ids: Vec<_> = (0..n).map(|i| g.add_spa(&format!("a{i}"))).collect();
    for w in ids.windows(2) {
        g.connect(w[0], w[1], 4 + rng.below(64), 1 + rng.below(6));
    }
    // Extra forward (skip) edges.
    for _ in 0..rng.below(n) {
        let i = rng.below(n - 1);
        let j = i + 1 + rng.below(n - 1 - i);
        g.connect(ids[i], ids[j], 4 + rng.below(64), 1 + rng.below(6));
    }
    g
}

#[test]
fn prop_homogeneous_dags_have_unit_repetition_vector() {
    forall(
        101,
        60,
        12,
        |rng, size| random_dag(rng, size),
        |g| {
            let reps = edge_prune::analyzer::sdf::repetition_vector(g)
                .map_err(|e| format!("{e}"))?;
            if reps.iter().all(|&q| q == 1) {
                Ok(())
            } else {
                Err(format!("non-unit repetition vector {reps:?}"))
            }
        },
    );
}

#[test]
fn prop_balance_equations_hold_for_multirate_chains() {
    // Random multirate chain: q[src]*prod == q[dst]*cons per edge.
    forall(
        202,
        60,
        8,
        |rng, size| {
            let n = size.clamp(2, 8);
            let mut g = AppGraph::new();
            let ids: Vec<_> = (0..n).map(|i| g.add_spa(&format!("a{i}"))).collect();
            for w in ids.windows(2) {
                let prod = 1 + rng.below(4) as u32;
                let cons = 1 + rng.below(4) as u32;
                // connect with asymmetric but consistent rates
                let cap = (prod.max(cons) as usize) * 4;
                g.connect_rated(w[0], w[1], 4, cap, RateSpec::fixed(prod), 0);
                let e = g.edges.len() - 1;
                let dst = g.edges[e].dst;
                g.actors[dst.actor.0].in_ports[dst.port].rate = RateSpec::fixed(cons);
            }
            g
        },
        |g| {
            let reps = edge_prune::analyzer::sdf::repetition_vector(g)
                .map_err(|e| format!("{e}"))?;
            for e in &g.edges {
                let prod = g.actors[e.src.actor.0].out_ports[e.src.port].rate.url as u64;
                let cons = g.actors[e.dst.actor.0].in_ports[e.dst.port].rate.url as u64;
                let lhs = reps[e.src.actor.0] * prod;
                let rhs = reps[e.dst.actor.0] * cons;
                if lhs != rhs {
                    return Err(format!("balance violated: {lhs} != {rhs}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_minimal_buffer_bounds_are_schedulable() {
    // The analyzer's minimal bounds, applied as capacities, must yield a
    // live schedule (no capacity-induced deadlock).
    forall(
        303,
        40,
        10,
        |rng, size| random_dag(rng, size),
        |g| {
            let reps = edge_prune::analyzer::sdf::repetition_vector(g)
                .map_err(|e| format!("{e}"))?;
            let bounds = edge_prune::analyzer::deadlock::minimal_buffer_bounds(g, &reps)
                .map_err(|e| format!("{e}"))?;
            let mut g2 = g.clone();
            for (e, b) in g2.edges.iter_mut().zip(&bounds) {
                e.capacity = (*b).max(1);
            }
            edge_prune::analyzer::deadlock::simulate_iteration(&g2, &reps)
                .map(|_| ())
                .map_err(|e| format!("bounds not schedulable: {e}"))
        },
    );
}

#[test]
fn prop_compiler_partitions_actors_and_pairs_fifos() {
    // For a random DAG and a random 2-device mapping: every original
    // actor appears on exactly one device; #tx == #rx == #crossing edges;
    // ports pair up; local subgraphs validate.
    forall(
        404,
        50,
        10,
        |rng, size| {
            let g = random_dag(rng, size);
            let mut mapping = Mapping::new();
            for a in &g.actors {
                mapping.assign(&a.name, if rng.bool(0.5) { "e" } else { "s" });
            }
            (g, mapping)
        },
        |(g, mapping)| {
            let mut pg = PlatformGraph::new();
            pg.add_device(DeviceModel::native("e"));
            pg.add_device(DeviceModel::native("s"));
            pg.add_link("e", "s", LinkModel::ideal());
            let plan = compile(g, &pg, mapping, 31_000).map_err(|e| format!("{e}"))?;
            // Actor partition.
            let mut seen = std::collections::BTreeSet::new();
            for dp in plan.per_device.values() {
                for a in &dp.original_actors {
                    if !seen.insert(a.clone()) {
                        return Err(format!("actor {a} on two devices"));
                    }
                }
            }
            if seen.len() != g.actors.len() {
                return Err("actor lost in partition".into());
            }
            // FIFO pairing.
            let crossing = g
                .edges
                .iter()
                .filter(|e| {
                    mapping.device_of(&g.actors[e.src.actor.0].name).unwrap()
                        != mapping.device_of(&g.actors[e.dst.actor.0].name).unwrap()
                })
                .count();
            let tx: usize = plan.per_device.values().map(|p| p.tx.len()).sum();
            let rx: usize = plan.per_device.values().map(|p| p.rx.len()).sum();
            if tx != crossing || rx != crossing {
                return Err(format!("tx {tx} rx {rx} crossing {crossing}"));
            }
            let mut tx_ports: Vec<u16> =
                plan.per_device.values().flat_map(|p| p.tx.iter().map(|t| t.port)).collect();
            let mut rx_ports: Vec<u16> =
                plan.per_device.values().flat_map(|p| p.rx.iter().map(|r| r.port)).collect();
            tx_ports.sort();
            rx_ports.sort();
            if tx_ports != rx_ports {
                return Err("unpaired FIFO ports".into());
            }
            // Local subgraphs validate.
            for dp in plan.per_device.values() {
                dp.graph.validate().map_err(|e| format!("{e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_explorer_cut_bytes_decrease_to_zero_at_full_local() {
    // For the vehicle model: cut_bytes at pp == n is always 0, and every
    // pp's cut matches the sum of edges crossing the prefix.
    let dir = edge_prune::models::manifest::Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let manifest = edge_prune::models::manifest::Manifest::load(&dir).unwrap();
    for model in ["vehicle", "ssd"] {
        let Ok(meta) = manifest.model(model) else { continue };
        let order = edge_prune::explorer::precedence_order(meta).unwrap();
        assert_eq!(edge_prune::explorer::cut_bytes(meta, &order, order.len()), 0);
        for pp in 1..=order.len() {
            let endpoint: std::collections::BTreeSet<&String> =
                order[..pp].iter().collect();
            let expect: usize = meta
                .edges
                .iter()
                .filter(|e| endpoint.contains(&e.src) != endpoint.contains(&e.dst))
                .map(|e| e.bytes)
                .sum();
            assert_eq!(edge_prune::explorer::cut_bytes(meta, &order, pp), expect);
        }
    }
}

#[test]
fn prop_fifo_random_ops_conserve_tokens() {
    use edge_prune::dataflow::Token;
    use edge_prune::runtime::fifo::Fifo;
    forall(
        505,
        40,
        200,
        |rng, size| {
            // A random schedule of pushes (true) and pops (false).
            (0..size).map(|_| rng.bool(0.6)).collect::<Vec<bool>>()
        },
        |ops| {
            let f = Fifo::new(8);
            let mut pushed = 0u64;
            let mut popped = 0u64;
            for &is_push in ops {
                if is_push {
                    if f.len() < 8 {
                        f.push(Token::new(vec![1], pushed));
                        pushed += 1;
                    }
                } else if f.try_pop_n(1).is_some() {
                    popped += 1;
                }
            }
            let remaining = f.len() as u64;
            if pushed == popped + remaining && f.max_occupancy() <= 8 {
                Ok(())
            } else {
                Err(format!("pushed {pushed} != popped {popped} + rem {remaining}"))
            }
        },
    );
}

#[test]
fn engine_propagates_kernel_errors() {
    use edge_prune::runtime::engine::Engine;
    use edge_prune::runtime::kernels::{ActorKernel, FireOutcome, SourceKernel};
    use std::collections::BTreeMap;
    struct FailingKernel;
    impl ActorKernel for FailingKernel {
        fn fire(
            &mut self,
            _i: &[Vec<edge_prune::dataflow::Token>],
            seq: u64,
        ) -> anyhow::Result<FireOutcome> {
            if seq >= 2 {
                anyhow::bail!("injected failure at frame {seq}");
            }
            Ok(FireOutcome::Produced(Vec::new()))
        }
    }
    let mut g = AppGraph::new();
    let src = g.add_spa("src");
    let bad = g.add_spa("bad");
    g.connect(src, bad, 4, 2);
    let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
    let mut kernels: BTreeMap<String, Box<dyn ActorKernel>> = BTreeMap::new();
    kernels.insert("src".into(), Box::new(SourceKernel::new(10, 4, 1, 1)));
    kernels.insert("bad".into(), Box::new(FailingKernel));
    let err = engine.run(kernels).unwrap_err();
    assert!(err.to_string().contains("injected failure"), "{err}");
}

// ---------------------------------------------------------------------
// Wire-protocol codec properties: every frame kind and both handshake
// layouts must survive the resumable decoders byte-for-byte, and
// hostile bytes (truncation, bit flips, garbage lengths) must produce
// a clean error or a "need more bytes" wait — never a panic, never a
// partial consume, never an over-read.
// ---------------------------------------------------------------------

use edge_prune::runtime::reactor::ByteBuf;
use edge_prune::server::protocol::{
    decode_frame, decode_handshake, encode_frame, encode_handshake, encode_trace_prefix,
    split_trace_prefix, Handshake, ReqKind, Resume, MAX_PAYLOAD,
};

fn random_kind(rng: &mut Rng) -> ReqKind {
    match rng.below(8) {
        0 => ReqKind::Infer,
        1 => ReqKind::Switch,
        2 => ReqKind::Ping,
        3 => ReqKind::Bye,
        4 => ReqKind::Export,
        5 => ReqKind::Import,
        6 => ReqKind::DeadlineInfer,
        _ => ReqKind::TracedInfer,
    }
}

fn random_ascii(rng: &mut Rng, max_len: usize) -> String {
    let n = rng.below(max_len + 1);
    (0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

fn random_handshake(rng: &mut Rng, size: usize) -> Handshake {
    let model = random_ascii(rng, size.min(48));
    let client = random_ascii(rng, size.min(48));
    let pp = rng.below(1 << 16);
    let mut h = if rng.bool(0.5) {
        Handshake::v2(&model, pp, &client)
    } else {
        Handshake::v3(&model, pp, &client, rng.next_u64() as u8)
    };
    if rng.bool(0.5) {
        h = h.with_resume(Resume {
            session_id: rng.next_u64(),
            token: rng.next_u64(),
            last_ack: rng.next_u64(),
        });
    }
    h
}

#[test]
fn prop_every_frame_kind_round_trips_through_the_resumable_decoder() {
    forall(
        606,
        80,
        64,
        |rng, size| {
            let kind = random_kind(rng);
            let payload: Vec<u8> = match kind {
                // Traced infers carry span context ahead of the bytes.
                ReqKind::TracedInfer => {
                    let mut p =
                        encode_trace_prefix(rng.next_u64(), rng.next_u64() as u32).to_vec();
                    p.extend((0..rng.below(size * 4 + 1)).map(|_| rng.next_u64() as u8));
                    p
                }
                // Deadline infers carry budget + priority ahead of them.
                ReqKind::DeadlineInfer => {
                    let mut p =
                        encode_deadline_prefix(rng.next_u64() as u32, rng.next_u64() as u8)
                            .to_vec();
                    p.extend((0..rng.below(size * 4 + 1)).map(|_| rng.next_u64() as u8));
                    p
                }
                _ => (0..rng.below(size * 4 + 1)).map(|_| rng.next_u64() as u8).collect(),
            };
            (rng.next_u64(), kind, payload, rng.below(4096))
        },
        |(seq, kind, payload, split_hint)| {
            let bytes = encode_frame(*seq, *kind, payload).map_err(|e| format!("{e}"))?;
            // Delivered split at an arbitrary point: the strict-prefix
            // chunk must decode to "wait" without touching the buffer,
            // and the remainder must complete the frame exactly.
            let split = split_hint % bytes.len();
            let mut buf = ByteBuf::new();
            buf.extend(&bytes[..split]);
            let before = buf.len();
            match decode_frame(&mut buf) {
                Ok(None) => {
                    if buf.len() != before {
                        return Err("partial decode consumed bytes".into());
                    }
                }
                Ok(Some(_)) => return Err("frame completed from a strict prefix".into()),
                Err(e) => return Err(format!("valid prefix rejected: {e}")),
            }
            buf.extend(&bytes[split..]);
            let f = decode_frame(&mut buf)
                .map_err(|e| format!("valid frame rejected: {e}"))?
                .ok_or("complete frame not decoded")?;
            if !buf.is_empty() {
                return Err(format!("{} bytes over-retained after the frame", buf.len()));
            }
            if (f.seq, f.kind, &f.payload) != (*seq, *kind, payload) {
                return Err("decoded frame differs from encoded".into());
            }
            if *kind == ReqKind::TracedInfer {
                let (tid, span, rest) =
                    split_trace_prefix(&f.payload).map_err(|e| format!("{e}"))?;
                let (etid, espan, erest) = split_trace_prefix(payload).unwrap();
                if (tid, span, rest) != (etid, espan, erest) {
                    return Err("trace prefix mangled".into());
                }
            }
            if *kind == ReqKind::DeadlineInfer {
                let (budget, prio, rest) =
                    split_deadline_prefix(&f.payload).map_err(|e| format!("{e}"))?;
                let (ebudget, eprio, erest) = split_deadline_prefix(payload).unwrap();
                if (budget, prio, rest) != (ebudget, eprio, erest) {
                    return Err("deadline prefix mangled".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_handshakes_round_trip_byte_by_byte_at_both_versions() {
    forall(
        707,
        80,
        48,
        |rng, size| random_handshake(rng, size),
        |h| {
            let bytes = encode_handshake(h).map_err(|e| format!("{e}"))?;
            let mut buf = ByteBuf::new();
            let mut decoded = None;
            for (i, b) in bytes.iter().enumerate() {
                buf.extend(&[*b]);
                match decode_handshake(&mut buf) {
                    Ok(Some(got)) => {
                        if i + 1 != bytes.len() {
                            return Err(format!("handshake completed at byte {i}"));
                        }
                        decoded = Some(got);
                    }
                    Ok(None) => {
                        if i + 1 == bytes.len() {
                            return Err("complete handshake not decoded".into());
                        }
                    }
                    Err(e) => return Err(format!("valid prefix rejected at byte {i}: {e}")),
                }
            }
            let got = decoded.ok_or("handshake never completed")?;
            if &got != h {
                return Err(format!("decoded {got:?} != encoded {h:?}"));
            }
            if !buf.is_empty() {
                return Err("bytes over-retained after the handshake".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_frame_length_field_is_validated_before_payload() {
    // A 13-byte header with a random declared length: the decoder must
    // refuse an over-bound length immediately (never wait for 64 MiB of
    // payload that will never come), wait on an in-bound one, and leave
    // the buffer untouched either way.
    forall(
        808,
        80,
        64,
        |rng, _| (rng.next_u64(), rng.below(8) as u8, rng.next_u64() as u32),
        |&(seq, kind, len)| {
            let mut header = Vec::with_capacity(13);
            header.extend_from_slice(&seq.to_le_bytes());
            header.push(kind);
            header.extend_from_slice(&len.to_le_bytes());
            let mut buf = ByteBuf::new();
            buf.extend(&header);
            match decode_frame(&mut buf) {
                Err(e) => {
                    if len <= MAX_PAYLOAD {
                        return Err(format!("in-bound length {len} rejected: {e}"));
                    }
                    if buf.len() != 13 {
                        return Err("error path consumed bytes".into());
                    }
                }
                Ok(Some(f)) => {
                    if len != 0 || !f.payload.is_empty() {
                        return Err(format!("decoded a frame missing {len} bytes"));
                    }
                }
                Ok(None) => {
                    if len == 0 || len > MAX_PAYLOAD {
                        return Err(format!("decoder waits on undecodable length {len}"));
                    }
                    if buf.len() != 13 {
                        return Err("waiting decode consumed bytes".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bit_flipped_frames_never_panic_or_over_read() {
    forall(
        909,
        120,
        48,
        |rng, size| {
            let payload: Vec<u8> =
                (0..rng.below(size * 2 + 1)).map(|_| rng.next_u64() as u8).collect();
            let mut bytes = encode_frame(rng.next_u64(), random_kind(rng), &payload).unwrap();
            let bit = rng.below(bytes.len() * 8);
            bytes[bit / 8] ^= 1 << (bit % 8);
            bytes
        },
        |bytes| {
            // Whatever the flip hit (seq, kind, length, payload), the
            // decoder must drain to a clean wait or error: every success
            // consumes exactly its frame, and a non-advance leaves the
            // buffer byte-for-byte intact.
            let mut buf = ByteBuf::new();
            buf.extend(bytes);
            loop {
                let before = buf.len();
                match decode_frame(&mut buf) {
                    Ok(Some(f)) => {
                        if before - buf.len() != 13 + f.payload.len() {
                            return Err("frame consumed wrong byte count".into());
                        }
                    }
                    Ok(None) | Err(_) => {
                        if buf.len() != before {
                            return Err("non-advancing decode mutated the buffer".into());
                        }
                        return Ok(());
                    }
                }
            }
        },
    );
}

#[test]
fn prop_garbage_never_panics_either_resumable_decoder() {
    forall(
        1010,
        120,
        96,
        |rng, size| (0..rng.below(size + 2)).map(|_| rng.next_u64() as u8).collect::<Vec<u8>>(),
        |garbage| {
            // Frame decoder: one burst, drained until it waits or errors
            // (each success strictly shrinks the buffer, so this ends).
            let mut buf = ByteBuf::new();
            buf.extend(garbage);
            loop {
                let before = buf.len();
                match decode_frame(&mut buf) {
                    Ok(Some(_)) => {
                        if buf.len() >= before {
                            return Err("successful decode consumed nothing".into());
                        }
                    }
                    Ok(None) | Err(_) => break,
                }
            }
            // Handshake decoder: byte-by-byte; an error would close the
            // connection, so the trickle stops there.
            let mut buf = ByteBuf::new();
            for b in garbage {
                buf.extend(&[*b]);
                if decode_handshake(&mut buf).is_err() {
                    return Ok(());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Sparse activation codec properties: the variable-length sparse-i8
// frame must round-trip at every tensor size a split point produces,
// stay within its dense-plus-header ceiling, and shrug off hostile
// index sections (truncation, trailing garbage, bit flips) with a
// clean error — never a panic, an over-read, or an out-of-bounds
// scatter.
// ---------------------------------------------------------------------

use edge_prune::runtime::wire::{self, WireDtype};

/// Random tensor spanning the regimes the threshold encoder branches
/// on: all-zero (RLE k=0), mostly-zero (RLE wins), moderately dense
/// (bitmap wins), and fully dense (dense fallback) — at sizes from
/// empty through a full synthetic split-point activation.
fn random_sparse_tensor(rng: &mut Rng, size: usize) -> Vec<f32> {
    let n = if rng.bool(0.15) { 1024 } else { rng.below(size * 8 + 2) };
    let density = match rng.below(4) {
        0 => 0.0,
        1 => 0.05,
        2 => 0.3,
        _ => 1.0,
    };
    (0..n)
        .map(|_| {
            if rng.bool(density) {
                ((rng.next_u64() % 4099) as f32 - 2049.0) / 97.0
            } else {
                0.0
            }
        })
        .collect()
}

#[test]
fn prop_sparse_frames_round_trip_within_the_ceiling_and_re_encode_exactly() {
    forall(
        1111,
        120,
        64,
        |rng, size| random_sparse_tensor(rng, size),
        |x| {
            let mut enc = Vec::new();
            wire::encode_activation(WireDtype::SparseI8, x, &mut enc);
            let ceiling = wire::encoded_len(WireDtype::SparseI8, x.len());
            if enc.len() > ceiling {
                return Err(format!("{} encoded bytes over ceiling {ceiling}", enc.len()));
            }
            let st = wire::sparse_stats(&enc).ok_or("own encoding unparsable")?;
            if st.elems != x.len() {
                return Err(format!("stats say {} elems, tensor has {}", st.elems, x.len()));
            }
            let mut y = vec![f32::NAN; x.len()];
            wire::decode_activation_into(WireDtype::SparseI8, &enc, &mut y)
                .map_err(|e| format!("own encoding rejected: {e}"))?;
            if y.iter().any(|v| !v.is_finite()) {
                return Err("decode left non-finite values".into());
            }
            // Re-encoding the decoded tensor reproduces the form byte,
            // index section, and codes byte-for-byte; only the stored
            // f32 scale may move by one ulp (127*s/127 is not exact in
            // f32).  The digest contract never re-encodes — each hop
            // encodes once and both sides decode the same payload — so
            // structural stability is the property that matters.
            let mut enc2 = Vec::new();
            wire::encode_activation(WireDtype::SparseI8, &y, &mut enc2);
            if enc2.len() != enc.len() || enc2[0] != enc[0] || enc2[5..] != enc[5..] {
                return Err("re-encode changed the frame structure".into());
            }
            let s1 = f32::from_le_bytes(enc[1..5].try_into().unwrap());
            let s2 = f32::from_le_bytes(enc2[1..5].try_into().unwrap());
            if (s2 - s1).abs() > s1.abs() * 1e-6 {
                return Err(format!("re-encoded scale drifted: {s1} -> {s2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mutated_sparse_payloads_error_cleanly_or_stay_in_bounds() {
    forall(
        1212,
        160,
        64,
        |rng, size| {
            let x = random_sparse_tensor(rng, size);
            let mut enc = Vec::new();
            wire::encode_activation(WireDtype::SparseI8, &x, &mut enc);
            match rng.below(4) {
                // Truncate anywhere (header, index section, codes).
                0 => enc.truncate(rng.below(enc.len() + 1)),
                // Trailing garbage past the declared structure.
                1 => enc.extend((0..1 + rng.below(16)).map(|_| rng.next_u64() as u8)),
                // Pure garbage of arbitrary length.
                2 => {
                    enc.clear();
                    enc.extend((0..rng.below(64)).map(|_| rng.next_u64() as u8));
                }
                // One flipped bit: form, scale, count, index, or code.
                _ => {
                    let bit = rng.below(enc.len() * 8);
                    enc[bit / 8] ^= 1 << (bit % 8);
                }
            }
            (x.len(), enc)
        },
        |(n, enc)| {
            // The parse-only validator and the decoder must agree on
            // every mutation: a payload decodes iff `sparse_stats`
            // accepts it at the right element count — and a decode that
            // runs at all stays in bounds (the harness would abort on a
            // panic or an out-of-range scatter).
            let st = wire::sparse_stats(enc);
            let mut out = vec![0.0f32; *n];
            let dec = wire::decode_activation_into(WireDtype::SparseI8, enc, &mut out);
            match (st, dec) {
                (Some(s), Ok(())) if s.elems == *n => Ok(()),
                (Some(s), Err(_)) if s.elems != *n => Ok(()),
                (None, Err(_)) => Ok(()),
                (st, dec) => Err(format!("stats {st:?} disagree with decode {dec:?}")),
            }
        },
    );
}

#[test]
fn sparse_negotiation_downgrades_old_peers_across_every_capability_mask() {
    // Exhaustive over both 8-bit capability masks: the negotiated dtype
    // is always mutually supported, never leaves a cheaper mutual dtype
    // on the table, and a peer that never learned the sparse bit (or
    // any v2 peer, which advertises no bits at all) silently lands on
    // the best dtype it does speak.
    for client in 0..=255u8 {
        for server in 0..=255u8 {
            let both = client & server;
            let dtype = wire::negotiate(client, server);
            let need = match dtype {
                WireDtype::F32 => 0,
                WireDtype::F16 => wire::CAP_F16,
                WireDtype::I8 => wire::CAP_I8,
                WireDtype::SparseI8 => wire::CAP_SPARSE_I8,
            };
            assert!(
                need == 0 || both & need != 0,
                "{dtype:?} negotiated without mutual capability ({client:#x}/{server:#x})"
            );
            if both & wire::CAP_SPARSE_I8 != 0 {
                assert_eq!(dtype, WireDtype::SparseI8, "sparse left on the table");
            } else if both & wire::CAP_I8 != 0 {
                assert_eq!(dtype, WireDtype::I8, "i8 left on the table");
            } else if both & wire::CAP_F16 != 0 {
                assert_eq!(dtype, WireDtype::F16, "f16 left on the table");
            } else {
                assert_eq!(dtype, WireDtype::F32, "no mutual bits must mean f32");
            }
        }
    }
    assert_eq!(wire::negotiate(0, u8::MAX), WireDtype::F32, "v2 peer downgrades to f32");
}

#[test]
fn prop_rng_below_is_uniform_enough() {
    // Sanity on the PRNG substrate the workloads depend on: chi-square-ish
    // bound over 8 buckets.
    let mut rng = Rng::new(999);
    let n = 80_000;
    let mut buckets = [0u32; 8];
    for _ in 0..n {
        buckets[rng.below(8)] += 1;
    }
    let expect = n as f64 / 8.0;
    for (i, &b) in buckets.iter().enumerate() {
        let dev = (b as f64 - expect).abs() / expect;
        assert!(dev < 0.05, "bucket {i}: {b} vs {expect}");
    }
}

// ---------------------------------------------------------------------
// Fleet-migration codec properties: the session image (Import payload),
// the Export target payload, and the MIGRATE hint must round-trip
// exactly, refuse truncation and trailing garbage with a clean error,
// and keep hostile bit flips either rejected or canonical — never a
// panic, never an over-read.  The capability gate must downgrade every
// v2 / no-CAP_MIGRATE peer combination.
// ---------------------------------------------------------------------

use edge_prune::server::protocol::{
    encode_session_image, export_payload, migrate_hint_payload, parse_export_payload,
    parse_migrate_hint, parse_session_image, MigrateHint, Response, SessionImage, VERSION,
};

fn random_image(rng: &mut Rng, size: usize) -> SessionImage {
    use edge_prune::runtime::wire::Precision;
    let mut seq = rng.below(4) as u64;
    let mut ring = Vec::new();
    for _ in 0..rng.below(size.min(8) + 1) {
        seq += 1 + rng.below(3) as u64;
        let body: Vec<u8> = (0..rng.below(24)).map(|_| rng.next_u64() as u8).collect();
        ring.push(if rng.bool(0.85) {
            Response::ok(seq, body)
        } else {
            Response::error(seq, "queue full")
        });
    }
    SessionImage {
        client_id: random_ascii(rng, 16),
        model: random_ascii(rng, 16),
        pp: rng.below(1 << 16),
        wire: match rng.below(4) {
            0 => WireDtype::F32,
            1 => WireDtype::F16,
            2 => WireDtype::I8,
            _ => WireDtype::SparseI8,
        },
        precision: if rng.bool(0.5) { Precision::F32 } else { Precision::Int8 },
        epoch: rng.next_u64(),
        last_ack: rng.next_u64(),
        ring,
    }
}

#[test]
fn prop_session_images_round_trip_and_refuse_every_truncation() {
    forall(
        1313,
        80,
        48,
        |rng, size| random_image(rng, size),
        |img| {
            let bytes = encode_session_image(img).map_err(|e| format!("{e}"))?;
            let got = parse_session_image(&bytes).map_err(|e| format!("own image rejected: {e}"))?;
            if &got != img {
                return Err(format!("decoded image differs: {got:?} != {img:?}"));
            }
            // Every strict prefix must error (the parser demands exact
            // consumption, so no truncation can silently drop ring
            // entries or shorten a string).
            for cut in 0..bytes.len() {
                if parse_session_image(&bytes[..cut]).is_ok() {
                    return Err(format!("truncation to {cut} bytes parsed"));
                }
            }
            // So must trailing garbage.
            let mut padded = bytes.clone();
            padded.push(0);
            if parse_session_image(&padded).is_ok() {
                return Err("trailing byte accepted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bit_flipped_session_images_error_or_stay_canonical() {
    forall(
        1414,
        120,
        48,
        |rng, size| {
            let mut bytes = encode_session_image(&random_image(rng, size)).unwrap();
            let bit = rng.below(bytes.len() * 8);
            bytes[bit / 8] ^= 1 << (bit % 8);
            bytes
        },
        |bytes| {
            // A flip may hit a don't-care byte (a body, an id) and still
            // parse — but then the encoding is canonical: re-encoding
            // the parsed image must reproduce the mutated bytes exactly.
            // Anything else (length fields, order, enums) errors cleanly.
            match parse_session_image(bytes) {
                Err(_) => Ok(()),
                Ok(img) => {
                    let re = encode_session_image(&img).map_err(|e| format!("{e}"))?;
                    if &re == bytes {
                        Ok(())
                    } else {
                        Err("accepted image re-encodes differently".into())
                    }
                }
            }
        },
    );
}

#[test]
fn prop_migrate_hints_and_export_targets_round_trip_and_reject_mutation() {
    forall(
        1515,
        100,
        48,
        |rng, size| {
            (
                MigrateHint {
                    addr: random_ascii(rng, size.min(40)),
                    session_id: rng.next_u64(),
                    token: rng.next_u64(),
                },
                rng.next_u64(),
            )
        },
        |(hint, salt)| {
            let body = migrate_hint_payload(hint).map_err(|e| format!("{e}"))?;
            let got = parse_migrate_hint(&body).map_err(|e| format!("own hint rejected: {e}"))?;
            if &got != hint {
                return Err(format!("decoded hint differs: {got:?}"));
            }
            for cut in 0..body.len() {
                if parse_migrate_hint(&body[..cut]).is_ok() {
                    return Err(format!("hint truncated to {cut} bytes parsed"));
                }
            }
            let mut padded = body.clone();
            padded.push(b'x');
            if parse_migrate_hint(&padded).is_ok() {
                return Err("hint with trailing byte accepted".into());
            }
            // A flip in the magic must unconditionally reject (that is
            // what shields pre-migrate replay handling from the hint).
            let mut magicless = body.clone();
            magicless[(salt % 4) as usize] ^= 0x20;
            if parse_migrate_hint(&magicless).is_ok() {
                return Err("hint with mangled magic accepted".into());
            }
            // The Export target payload: same round-trip + strictness.
            let t = export_payload(&hint.addr).map_err(|e| format!("{e}"))?;
            let back = parse_export_payload(&t).map_err(|e| format!("{e}"))?;
            if back != hint.addr {
                return Err("export target mangled".into());
            }
            for cut in 0..t.len() {
                if parse_export_payload(&t[..cut]).is_ok() {
                    return Err(format!("export target truncated to {cut} parsed"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn migrate_grant_downgrades_every_old_peer_combination() {
    use edge_prune::runtime::wire::CAP_MIGRATE;
    use edge_prune::server::protocol::migrate_granted;
    // Exhaustive over version x both capability masks: migration is
    // granted exactly when the session is v3+ and BOTH sides advertise
    // CAP_MIGRATE — a v2 peer, or a v3 peer built before the fleet bit,
    // always lands on plain reconnect.
    for version in [1u16, 2, VERSION, VERSION + 1] {
        for client in 0..=255u8 {
            for server in 0..=255u8 {
                let want = version >= VERSION
                    && client & CAP_MIGRATE != 0
                    && server & CAP_MIGRATE != 0;
                assert_eq!(
                    migrate_granted(version, client, server),
                    want,
                    "v{version} {client:#x}/{server:#x}"
                );
            }
        }
    }
}

#[test]
fn prop_export_and_import_frames_survive_the_resumable_decoder_at_every_split() {
    // The two fleet frame kinds with their real payloads (an export
    // target, a full session image) through the same split-point
    // discipline every other kind gets: a strict prefix waits without
    // consuming, the remainder completes byte-for-byte.
    forall(
        1616,
        80,
        32,
        |rng, size| {
            let (kind, payload) = if rng.bool(0.5) {
                (ReqKind::Export, export_payload(&random_ascii(rng, 40)).unwrap())
            } else {
                (ReqKind::Import, encode_session_image(&random_image(rng, size)).unwrap())
            };
            (rng.next_u64(), kind, payload, rng.below(1 << 16))
        },
        |(seq, kind, payload, split_hint)| {
            let bytes = encode_frame(*seq, *kind, payload).map_err(|e| format!("{e}"))?;
            let split = split_hint % bytes.len();
            let mut buf = ByteBuf::new();
            buf.extend(&bytes[..split]);
            match decode_frame(&mut buf) {
                Ok(None) => {}
                Ok(Some(_)) => return Err("frame completed from a strict prefix".into()),
                Err(e) => return Err(format!("valid prefix rejected: {e}")),
            }
            buf.extend(&bytes[split..]);
            let f = decode_frame(&mut buf)
                .map_err(|e| format!("valid frame rejected: {e}"))?
                .ok_or("complete frame not decoded")?;
            if (f.seq, f.kind, &f.payload) != (*seq, *kind, payload) {
                return Err("decoded frame differs from encoded".into());
            }
            // And the payload still parses to the same structure on the
            // far side of the frame layer.
            match kind {
                ReqKind::Export => {
                    parse_export_payload(&f.payload).map_err(|e| format!("{e}"))?;
                }
                _ => {
                    parse_session_image(&f.payload).map_err(|e| format!("{e}"))?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Overload-control codec properties: the 5-byte deadline prefix and the
// SHED body (retry-after + reason) must round-trip exactly, refuse
// truncation with a clean error, and the CAP_DEADLINE grant must
// downgrade every v2 / no-bit peer combination — the same discipline
// the trace prefix and the migrate grant already uphold.
// ---------------------------------------------------------------------

use edge_prune::server::protocol::{
    deadline_granted, encode_deadline_prefix, parse_shed_body, split_deadline_prefix,
    DEADLINE_PREFIX,
};

#[test]
fn prop_deadline_prefixes_are_canonical_and_reject_truncation() {
    forall(
        1717,
        120,
        64,
        |rng, _| (rng.next_u64() as u32, rng.next_u64() as u8),
        |&(budget, prio)| {
            let p = encode_deadline_prefix(budget, prio);
            if p.len() != DEADLINE_PREFIX {
                return Err("prefix length drifted from DEADLINE_PREFIX".into());
            }
            let (b, pr, rest) = split_deadline_prefix(&p).map_err(|e| format!("{e}"))?;
            if (b, pr) != (budget, prio) || !rest.is_empty() {
                return Err(format!("round trip mangled: {b}/{pr}"));
            }
            // With a body attached, the split hands back exactly the body.
            let mut framed = p.to_vec();
            framed.extend_from_slice(&[9, 8, 7]);
            let (b, pr, rest) = split_deadline_prefix(&framed).map_err(|e| format!("{e}"))?;
            if (b, pr) != (budget, prio) || rest != [9, 8, 7] {
                return Err("split consumed body bytes".into());
            }
            // Every strict prefix of the header errors cleanly — a torn
            // deadline must never parse as a shorter budget.
            for cut in 0..DEADLINE_PREFIX {
                if split_deadline_prefix(&p[..cut]).is_ok() {
                    return Err(format!("truncation to {cut} bytes parsed"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shed_bodies_round_trip_and_reject_truncation() {
    forall(
        1818,
        100,
        48,
        |rng, size| (rng.next_u64(), rng.next_u64() as u32, random_ascii(rng, size.min(40))),
        |(req_id, retry_ms, why)| {
            let resp = Response::shed(*req_id, *retry_ms, why);
            let (ms, reason) = parse_shed_body(&resp.body).map_err(|e| format!("{e}"))?;
            if ms != *retry_ms || &reason != why {
                return Err(format!("shed body mangled: {ms} '{reason}'"));
            }
            // The 4 retry-after bytes are mandatory: anything shorter
            // errors instead of inventing a hint.
            for cut in 0..4.min(resp.body.len()) {
                if parse_shed_body(&resp.body[..cut]).is_ok() {
                    return Err(format!("truncation to {cut} bytes parsed"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn deadline_grant_downgrades_every_old_peer_combination() {
    use edge_prune::runtime::wire::CAP_DEADLINE;
    // Exhaustive over version x both capability masks, the same matrix
    // the migrate grant passes: deadlines are granted exactly when the
    // session is v3+ and BOTH sides advertise CAP_DEADLINE.
    for version in [1u16, 2, VERSION, VERSION + 1] {
        for client in 0..=255u8 {
            for server in 0..=255u8 {
                let want = version >= VERSION
                    && client & CAP_DEADLINE != 0
                    && server & CAP_DEADLINE != 0;
                assert_eq!(
                    deadline_granted(version, client, server),
                    want,
                    "v{version} {client:#x}/{server:#x}"
                );
            }
        }
    }
}
