//! Fig. 6 — SSD-Mobilenet object-tracking endpoint inference time,
//! N2 <-> i7, at partition points along the MobileNet backbone.
//!
//! Paper reference points: full endpoint 2360 ms; Ethernet optimum =
//! Input..DWCL9 on the endpoint (PP11 here) at 406 ms -> 5.8x speedup;
//! WiFi optimum at PP9 (Input..DWCL7) at 470 ms.
//! Env knobs: EP_FRAMES (default 3), EP_TIME_SCALE (1.5),
//! EP_SSD_PPS (comma list, default backbone sweep).

use edge_prune::benchkit::{env_or, header, row};
use edge_prune::explorer::{format_table, sweep, SweepConfig};
use edge_prune::models::manifest::Manifest;
use edge_prune::platform::configs::Configs;
use edge_prune::runtime::wire::WireDtype;
use edge_prune::runtime::xla_exec::Variant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let configs = Configs::load_default()?;
    let frames: u64 = env_or("EP_FRAMES", 4);
    let time_scale: f64 = env_or("EP_TIME_SCALE", 3.0);
    // PPs over the backbone: PP k = first k of [input, conv1, dwcl1..13].
    let pps: Vec<usize> = match std::env::var("EP_SSD_PPS") {
        Ok(s) => s.split(',').map(|x| x.trim().parse().unwrap()).collect(),
        Err(_) => vec![1, 2, 3, 5, 7, 8, 9, 10, 11, 12, 13, 15],
    };

    header("Fig. 6: SSD-Mobilenet object tracking, N2 endpoint <-> i7 server");
    println!("(compiling 2x34 HLO executables once; sweeping {} PPs)", pps.len());
    let mut summaries = Vec::new();
    for (link_name, base_port) in [("n2_i7_eth", 24_000u16), ("n2_i7_wifi", 26_000u16)] {
        let cfg = SweepConfig {
            model: "ssd".into(),
            endpoint: configs.device("n2", "ssd")?,
            server: configs.device("i7", "ssd")?,
            link: configs.link(link_name)?,
            frames,
            pps: pps.clone(),
            base_port,
            variant: Variant::Jnp,
            time_scale,
            seed: 6,
            wire: WireDtype::F32,
        };
        let report = sweep(&manifest, &cfg)?;
        print!("{}", format_table(&report));
        summaries.push(report);
    }

    header("Fig. 6 paper-vs-measured checkpoints");
    let (eth, wifi) = (&summaries[0], &summaries[1]);
    let at = |r: &edge_prune::explorer::SweepReport, pp: usize| {
        r.results.iter().find(|x| x.pp == pp).map(|x| x.endpoint_ms).unwrap_or(f64::NAN)
    };
    println!("{}", row("full endpoint inference", 2360.0, eth.full_endpoint_ms, "ms"));
    println!("{}", row("PP11 (Input..DWCL9, Ethernet)", 406.0, at(eth, 11), "ms"));
    println!("{}", row("PP9 (Input..DWCL7, WiFi)", 470.0, at(wifi, 9), "ms"));
    let best_eth = eth.best().unwrap();
    let best_wifi = wifi.best().unwrap();
    println!(
        "Ethernet best: paper PP11/406 ms (5.8x); measured PP{} / {:.0} ms ({:.1}x)",
        best_eth.pp,
        best_eth.endpoint_ms,
        eth.full_endpoint_ms / best_eth.endpoint_ms
    );
    println!(
        "WiFi best: paper PP9/470 ms; measured PP{} / {:.0} ms ({:.1}x)",
        best_wifi.pp,
        best_wifi.endpoint_ms,
        wifi.full_endpoint_ms / best_wifi.endpoint_ms
    );
    println!(
        "collaborative >> full-endpoint on both links: {}",
        best_eth.endpoint_ms < 0.5 * eth.full_endpoint_ms
            && best_wifi.endpoint_ms < 0.5 * wifi.full_endpoint_ms
    );
    Ok(())
}
