//! Serving throughput: requests/sec vs concurrent client count against
//! one in-process edge inference server (synthetic split model).
//!
//! Knobs: EP_REQUESTS (per client), EP_PP (partition point), EP_WORKERS
//! (0 = one per core), EP_PIN (1 = pin workers to cores).

use edge_prune::benchkit::{env_or, header};
use edge_prune::server::loadgen::{run_loadgen, LoadgenConfig};
use edge_prune::server::{Server, ServerConfig};

fn main() -> anyhow::Result<()> {
    let requests: u64 = env_or("EP_REQUESTS", 200u64);
    let pp: usize = env_or("EP_PP", 3usize);
    let workers: usize = env_or("EP_WORKERS", 0usize);
    let pin: usize = env_or("EP_PIN", 1usize);

    header(&format!(
        "server throughput: requests/sec vs clients (pp {pp}, {requests} req/client, \
         workers {})",
        if workers == 0 { "auto".to_string() } else { workers.to_string() }
    ));
    println!("clients   req/s   p50-ms   p95-ms   p99-ms   batch-occ   rejected");

    for clients in [1usize, 4, 8] {
        let server = Server::start(ServerConfig {
            workers,
            pin_workers: pin != 0,
            ..ServerConfig::default()
        })?;
        let report = run_loadgen(&LoadgenConfig {
            addr: server.addr().to_string(),
            clients,
            requests,
            pp,
            seed: 42,
            ..LoadgenConfig::default()
        })?;
        anyhow::ensure!(report.lost() == 0, "lost requests at {clients} clients");
        anyhow::ensure!(report.errors == 0, "response mismatches at {clients} clients");
        let metrics = server.shutdown();
        let occupancy = metrics.get("batch_occupancy")?.num()?;
        println!(
            "{clients:>7} {:>7.0} {:>8.2} {:>8.2} {:>8.2} {:>11.2} {:>10}",
            report.requests_per_sec(),
            report.latency.quantile_ms(0.50),
            report.latency.quantile_ms(0.95),
            report.latency.quantile_ms(0.99),
            occupancy,
            report.rejected,
        );
    }
    Ok(())
}
