//! Sparse activation wire bench: measured encoded payload bytes vs
//! dense int8 at every partition point, agreement with the plan-build
//! sparsity calibration, digest accuracy of the sparse codec vs pure
//! f32, and (when the XLA artifacts are present) the explorer's
//! predicted-optimum shift from pricing cuts at the calibrated
//! expected size.  Emits `BENCH_sparse.json`.
//!
//! CI smoke assertions (EXPERIMENTS.md "Sparse wire" has the
//! methodology):
//! * the measured sparse payload is >= `EP_SPARSE_MIN_RATIO`x smaller
//!   than the dense int8 payload at EVERY partition point (default 2 —
//!   the top-k budget keeps <= 1/4 of the coefficients and the cheaper
//!   index form costs at most 1 bit + 1 byte per kept element);
//! * plan-build calibration prices every pp at <= half the dense int8
//!   payload, so the explorer never flatters the sparse wire;
//! * digest top-1 agreement of the sparse wire (f32 compute) at the
//!   default pp over `EP_SPARSE_FRAMES` fixed-seed frames >=
//!   `EP_SPARSE_MIN_TOP1` (default 1.0 — the f32 digest's argmax
//!   margin is ~2.9 on the synthetic model, far above the sparse
//!   epsilon at the serving pp) and its epsilon stays under
//!   `EP_SPARSE_MAX_EPS` (default 1.0; measured ~0.45 at pp 3 — the
//!   epsilon grows toward late cuts because less of the contraction
//!   chain remains to damp the dropped coefficients, so the per-pp
//!   rows are recorded, not gated);
//! * with artifacts: the explorer's best sparse endpoint on the
//!   vehicle N2/Ethernet sweep is no worse than the best dense-int8
//!   endpoint, and the cut at that point shrinks >= the same ratio
//!   floor.
//!
//! Knobs: EP_SPARSE_FRAMES (16), EP_SPARSE_MIN_RATIO,
//! EP_SPARSE_MIN_TOP1, EP_SPARSE_MAX_EPS.

use edge_prune::benchkit::{env_or, header, write_bench_json};
use edge_prune::explorer::{precedence_order, predict_endpoint_ms, wire_cut_bytes};
use edge_prune::models::manifest::Manifest;
use edge_prune::runtime::device::DeviceModel;
use edge_prune::runtime::netsim::LinkModel;
use edge_prune::runtime::wire::{self, Precision, SessionCodec, WireDtype};
use edge_prune::server::model::{
    calibrated_sparsity, client_prepare_codec, expected_digest_codec, make_input, MAX_PP,
    TOKEN_FLOATS,
};
use edge_prune::util::json::Json;
use edge_prune::util::tensor::bytes_to_f32;

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if *x > v[best] {
            best = i;
        }
    }
    best
}

/// Digest accuracy of one sparse codec vs pure f32 over fixed seeds:
/// (max abs error, top-1 agreement fraction).
fn accuracy(codec: SessionCodec, pp: usize, frames: u64) -> (f64, f64) {
    let f32_codec = SessionCodec::f32();
    let mut max_err = 0.0f64;
    let mut agree = 0u64;
    for seed in 0..frames {
        let input = make_input(seed);
        let base = bytes_to_f32(&expected_digest_codec(&input, pp, f32_codec));
        let got = bytes_to_f32(&expected_digest_codec(&input, pp, codec));
        for (a, b) in base.iter().zip(&got) {
            max_err = max_err.max((a - b).abs() as f64);
        }
        if argmax(&base) == argmax(&got) {
            agree += 1;
        }
    }
    (max_err, agree as f64 / frames as f64)
}

fn main() -> anyhow::Result<()> {
    let frames: u64 = env_or("EP_SPARSE_FRAMES", 16u64);
    let min_ratio: f64 = env_or("EP_SPARSE_MIN_RATIO", 2.0f64);
    let min_top1: f64 = env_or("EP_SPARSE_MIN_TOP1", 1.0f64);
    let max_eps: f64 = env_or("EP_SPARSE_MAX_EPS", 1.0f64);
    let gated_pp = 3usize; // the serving default partition point

    header("sparse wire: measured encoded bytes + accuracy vs dense int8");

    // The config a sparse session actually serves with (int8 stage
    // compute) measures the bytes; the wire-only config isolates the
    // codec's own accuracy cost from int8-GEMM noise for the gate.
    let full_sparse = SessionCodec { wire: WireDtype::SparseI8, precision: Precision::Int8 };
    let sparse_wire = SessionCodec { wire: WireDtype::SparseI8, precision: Precision::F32 };
    let dense_bytes = wire::encoded_len(WireDtype::I8, TOKEN_FLOATS);

    let mut rows = Vec::new();
    let mut worst_ratio = f64::INFINITY;
    let mut max_cal_bytes = 0usize;
    let mut gated = (0.0f64, 0.0f64);
    println!(
        "{:<3} {:>9} {:>7} {:>8} {:>7} {:>10} {:>6} {:>10} {:>6}",
        "pp", "bytes", "cal_B", "density", "ratio", "eps_wire", "top1", "eps_int8", "top1"
    );
    for pp in 1..=MAX_PP {
        let (mut bytes, mut elems, mut nnz) = (0u64, 0u64, 0u64);
        for seed in 0..frames {
            let input = make_input(seed);
            let payload = client_prepare_codec(&input, pp, full_sparse);
            let st = wire::sparse_stats(&payload).expect("own encoding is well-formed");
            bytes += payload.len() as u64;
            elems += st.elems as u64;
            nnz += st.nnz as u64;
        }
        let mean_bytes = bytes as f64 / frames as f64;
        let density = nnz as f64 / elems as f64;
        let ratio = dense_bytes as f64 / mean_bytes;
        worst_ratio = worst_ratio.min(ratio);
        let cal = calibrated_sparsity(pp).expect("pp in range");
        max_cal_bytes = max_cal_bytes.max(cal.expected_bytes);
        let (weps, wtop1) = accuracy(sparse_wire, pp, frames);
        let (qeps, qtop1) = accuracy(full_sparse, pp, frames);
        if pp == gated_pp {
            gated = (weps, wtop1);
        }
        println!(
            "{:<3} {:>9.1} {:>7} {:>8.3} {:>6.2}x {:>10.2e} {:>6.2} {:>10.2e} {:>6.2}",
            pp, mean_bytes, cal.expected_bytes, density, ratio, weps, wtop1, qeps, qtop1
        );
        rows.push(Json::from_pairs(vec![
            ("pp", Json::from(pp)),
            ("mean_payload_bytes", Json::from(mean_bytes)),
            ("calibrated_bytes", Json::from(cal.expected_bytes)),
            ("calibrated_density", Json::from(cal.density)),
            ("measured_density", Json::from(density)),
            ("ratio_vs_dense_i8", Json::from(ratio)),
            ("digest_eps_sparse_wire", Json::from(weps)),
            ("top1_sparse_wire", Json::from(wtop1)),
            ("digest_eps_full_sparse_int8", Json::from(qeps)),
            ("top1_full_sparse_int8", Json::from(qtop1)),
        ]));
    }
    println!(
        "worst-pp payload ratio {worst_ratio:.2}x (floor {min_ratio}x); \
         gated pp {gated_pp}: eps {:.3} (cap {max_eps}), top-1 {:.2} (floor {min_top1})",
        gated.0, gated.1
    );

    // ---- Explorer: the vehicle N2/Ethernet sweep priced at int8 vs
    // sparse.  Skipped when the XLA artifacts are absent (e.g. CI).
    let dir = Manifest::default_dir();
    let mut explorer_gate = None;
    let explorer_json = if dir.join("manifest.json").exists() {
        let meta = Manifest::load(&dir)?.model("vehicle")?.clone();
        let order = precedence_order(&meta)?;
        let mut n2 = DeviceModel::native("n2");
        n2.cores = 6;
        for (a, ms) in [("input", 0.5), ("l1", 6.2), ("l2", 8.2), ("l3", 2.5), ("l45", 1.5)] {
            n2.cost_ms.insert(a.to_string(), ms);
        }
        let eth = LinkModel::new("eth", 11.2, 1.49);
        let best = |dtype: WireDtype| -> (usize, f64) {
            (1..=order.len())
                .map(|pp| (pp, predict_endpoint_ms(&meta, &n2, &eth, &order, pp, dtype)))
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap()
        };
        let (i8_pp, i8_ms) = best(WireDtype::I8);
        let (sp_pp, sp_ms) = best(WireDtype::SparseI8);
        let i8_cut = wire_cut_bytes(&meta, &order, sp_pp, WireDtype::I8);
        let sp_cut = wire_cut_bytes(&meta, &order, sp_pp, WireDtype::SparseI8);
        println!(
            "explorer (vehicle, N2/eth): best int8 pp {i8_pp} ({i8_ms:.2} ms) -> best sparse \
             pp {sp_pp} ({sp_ms:.2} ms); cut at sparse best: {sp_cut} B vs {i8_cut} B int8"
        );
        explorer_gate = Some((sp_ms, i8_ms, sp_cut, i8_cut));
        Json::from_pairs(vec![
            ("best_pp_i8", Json::from(i8_pp)),
            ("best_ms_i8", Json::from(i8_ms)),
            ("best_pp_sparse", Json::from(sp_pp)),
            ("best_ms_sparse", Json::from(sp_ms)),
            ("cut_bytes_i8_at_sparse_best", Json::from(i8_cut)),
            ("cut_bytes_sparse_at_sparse_best", Json::from(sp_cut)),
        ])
    } else {
        println!(
            "explorer: {} missing -- prediction sweep skipped",
            dir.join("manifest.json").display()
        );
        Json::Null
    };

    let out = Json::from_pairs(vec![
        ("bench", Json::from("sparse_wire")),
        ("frames", Json::from(frames)),
        ("dense_i8_payload_bytes", Json::from(dense_bytes)),
        ("keep_budget", Json::from(1.0 / wire::SPARSE_KEEP_DIV as f64)),
        ("worst_pp_ratio", Json::from(worst_ratio)),
        ("gated_pp", Json::from(gated_pp)),
        ("digest_eps_sparse_wire_at_gated_pp", Json::from(gated.0)),
        ("top1_sparse_wire_at_gated_pp", Json::from(gated.1)),
        ("per_pp", Json::from(rows)),
        ("explorer", explorer_json),
    ]);
    write_bench_json("sparse", &out)?;

    anyhow::ensure!(
        worst_ratio >= min_ratio,
        "sparse payload only {worst_ratio:.2}x under dense int8 (floor {min_ratio}x)"
    );
    anyhow::ensure!(
        max_cal_bytes * 2 <= dense_bytes,
        "calibration prices {max_cal_bytes} B at some pp, over half the dense {dense_bytes} B"
    );
    anyhow::ensure!(
        gated.1 >= min_top1,
        "sparse-wire top-1 agreement {:.3} at pp {gated_pp} under floor {min_top1}",
        gated.1
    );
    anyhow::ensure!(
        gated.0 < max_eps,
        "sparse-wire digest eps {:.3} at pp {gated_pp} out of bounds (cap {max_eps})",
        gated.0
    );
    if let Some((sp_ms, i8_ms, sp_cut, i8_cut)) = explorer_gate {
        anyhow::ensure!(
            sp_ms <= i8_ms,
            "sparse best endpoint {sp_ms:.3} ms worse than int8 best {i8_ms:.3} ms"
        );
        if i8_cut > 0 {
            let r = i8_cut as f64 / sp_cut as f64;
            anyhow::ensure!(
                r >= min_ratio,
                "vehicle best-pp cut only {r:.2}x under dense int8 (floor {min_ratio}x)"
            );
        }
    }
    Ok(())
}
