//! Quantization bench: int8 GEMM throughput vs the f32 blocked kernel
//! at 256^3, wire bytes-per-inference at the default partition point,
//! and the accuracy epsilon / top-1 agreement of the quantized serving
//! paths vs pure f32.  Emits `BENCH_quant.json`.
//!
//! CI smoke assertions (EXPERIMENTS.md "Quantization" has the
//! methodology):
//! * int8 blocked GEMM >= `EP_QUANT_MIN_SPEEDUP`x the f32 blocked GEMM
//!   at the same shape (default 2 — the vpmaddwd microkernel retires
//!   two MACs per multiply where f32 FMA retires one);
//! * int8 wire moves >= `EP_MIN_WIRE_RATIO`x fewer bytes per inference
//!   than f32 at the default pp (default 3.5);
//! * digest top-1 agreement of the default quantized serving config
//!   (i8 wire, f32 compute) over `EP_QUANT_FRAMES` fixed-seed frames
//!   >= `EP_QUANT_MIN_TOP1` (default 1.0 — exact agreement).
//!
//! Knobs: EP_GEMM_N (256), EP_ITERS (5), EP_QUANT_FRAMES (16),
//! EP_QUANT_MIN_SPEEDUP, EP_MIN_WIRE_RATIO, EP_QUANT_MIN_TOP1.

use edge_prune::benchkit::{env_or, header, stats, time_iters, write_bench_json};
use edge_prune::runtime::linalg::{
    gemm_blocked, gemm_flops, gemm_i8_blocked, GemmScratch, GemmScratchI8,
};
use edge_prune::runtime::wire::{Precision, SessionCodec, WireDtype};
use edge_prune::server::model::{expected_digest_codec, make_input, OUT_BYTES, TOKEN_FLOATS};
use edge_prune::util::json::Json;
use edge_prune::util::rng::Rng;
use edge_prune::util::tensor::bytes_to_f32;

/// Per-inference frame bytes at `dtype`: the infer request (13-byte
/// header + coded activation) plus the response (13-byte header + f32
/// digest, codec-independent).
fn frame_bytes(dtype: WireDtype) -> usize {
    13 + edge_prune::runtime::wire::encoded_len(dtype, TOKEN_FLOATS) + 13 + OUT_BYTES
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if *x > v[best] {
            best = i;
        }
    }
    best
}

/// Digest accuracy of one quantized codec vs pure f32 over fixed seeds:
/// (max abs error, top-1 agreement fraction).
fn accuracy(codec: SessionCodec, pp: usize, frames: u64) -> (f64, f64) {
    let f32_codec = SessionCodec::f32();
    let mut max_err = 0.0f64;
    let mut agree = 0u64;
    for seed in 0..frames {
        let input = make_input(seed);
        let base = bytes_to_f32(&expected_digest_codec(&input, pp, f32_codec));
        let quant = bytes_to_f32(&expected_digest_codec(&input, pp, codec));
        for (a, b) in base.iter().zip(&quant) {
            max_err = max_err.max((a - b).abs() as f64);
        }
        if argmax(&base) == argmax(&quant) {
            agree += 1;
        }
    }
    (max_err, agree as f64 / frames as f64)
}

fn main() -> anyhow::Result<()> {
    let n: usize = env_or("EP_GEMM_N", 256usize);
    let iters: usize = env_or("EP_ITERS", 5usize);
    let frames: u64 = env_or("EP_QUANT_FRAMES", 16u64);
    let min_speedup: f64 = env_or("EP_QUANT_MIN_SPEEDUP", 2.0f64);
    let min_wire_ratio: f64 = env_or("EP_MIN_WIRE_RATIO", 3.5f64);
    let min_top1: f64 = env_or("EP_QUANT_MIN_TOP1", 1.0f64);
    let pp = 3usize; // the serving default partition point

    header(&format!("quantization: int8 vs f32 GEMM {n}^3, wire bytes at pp {pp}"));

    // ---- GEMM: f32 blocked vs int8 blocked, single-threaded, same shape.
    let mut rng = Rng::new(7);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let aq: Vec<i8> = a.iter().map(|v| (v * 127.0).round() as i8).collect();
    let bq: Vec<i8> = b.iter().map(|v| (v * 127.0).round() as i8).collect();
    let mut c = vec![0.0f32; n * n];
    let mut cq = vec![0i32; n * n];
    let flops = gemm_flops(n, n, n);

    let mut fs = GemmScratch::new();
    let f32_ms =
        stats(&time_iters(1, iters, || gemm_blocked(n, n, n, &a, &b, &mut c, &mut fs))).p50;
    let mut qs = GemmScratchI8::new();
    let i8_ms =
        stats(&time_iters(1, iters, || gemm_i8_blocked(n, n, n, &aq, &bq, &mut cq, &mut qs))).p50;
    let f32_gf = flops as f64 / (f32_ms * 1e6);
    let i8_gf = flops as f64 / (i8_ms * 1e6);
    let speedup = i8_gf / f32_gf.max(1e-9);
    println!("{:<22} {:>10.2} ms/iter {:>10.2} GFLOP/s-eq", "gemm_f32_blocked", f32_ms, f32_gf);
    println!("{:<22} {:>10.2} ms/iter {:>10.2} GFLOP/s-eq", "gemm_i8_blocked", i8_ms, i8_gf);
    println!("int8/f32 GEMM speedup: {speedup:.2}x (floor {min_speedup}x)");

    // ---- Wire bytes per inference at the default pp.
    let f32_bytes = frame_bytes(WireDtype::F32);
    let i8_bytes = frame_bytes(WireDtype::I8);
    let f16_bytes = frame_bytes(WireDtype::F16);
    let wire_ratio = f32_bytes as f64 / i8_bytes as f64;
    println!(
        "bytes/infer at pp {pp}: f32 {f32_bytes}, f16 {f16_bytes}, int8 {i8_bytes} \
         -> {wire_ratio:.2}x fewer (floor {min_wire_ratio}x)"
    );

    // ---- Accuracy: quantized serving digests vs pure f32.
    let i8_wire = SessionCodec { wire: WireDtype::I8, precision: Precision::F32 };
    let f16_wire = SessionCodec { wire: WireDtype::F16, precision: Precision::F32 };
    let full_int8 = SessionCodec { wire: WireDtype::I8, precision: Precision::Int8 };
    let (i8_eps, i8_top1) = accuracy(i8_wire, pp, frames);
    let (f16_eps, f16_top1) = accuracy(f16_wire, pp, frames);
    let (int8_eps, int8_top1) = accuracy(full_int8, pp, frames);
    println!("digest eps vs f32 over {frames} frames (top-1 agreement):");
    println!("  f16 wire            {f16_eps:.2e} ({:.0}%)", f16_top1 * 100.0);
    println!("  i8 wire             {i8_eps:.2e} ({:.0}%)", i8_top1 * 100.0);
    println!("  i8 wire + int8 GEMM {int8_eps:.2e} ({:.0}%)", int8_top1 * 100.0);

    let out = Json::from_pairs(vec![
        ("bench", Json::from("quant_speedup")),
        ("gemm_n", Json::from(n)),
        ("iters", Json::from(iters)),
        ("frames", Json::from(frames)),
        ("f32_gemm_ms", Json::from(f32_ms)),
        ("i8_gemm_ms", Json::from(i8_ms)),
        ("int8_gemm_speedup", Json::from(speedup)),
        ("pp", Json::from(pp)),
        ("bytes_per_infer_f32", Json::from(f32_bytes)),
        ("bytes_per_infer_f16", Json::from(f16_bytes)),
        ("bytes_per_infer_i8", Json::from(i8_bytes)),
        ("wire_ratio", Json::from(wire_ratio)),
        ("digest_eps_f16_wire", Json::from(f16_eps)),
        ("digest_eps_i8_wire", Json::from(i8_eps)),
        ("digest_eps_full_int8", Json::from(int8_eps)),
        ("top1_agreement_f16_wire", Json::from(f16_top1)),
        ("top1_agreement_i8_wire", Json::from(i8_top1)),
        ("top1_agreement_full_int8", Json::from(int8_top1)),
    ]);
    write_bench_json("quant", &out)?;

    anyhow::ensure!(
        speedup >= min_speedup,
        "int8 GEMM only {speedup:.2}x f32 (floor {min_speedup}x)"
    );
    anyhow::ensure!(
        wire_ratio >= min_wire_ratio,
        "int8 wire only {wire_ratio:.2}x fewer bytes (floor {min_wire_ratio}x)"
    );
    // The default quantized serving config (i8 wire, f32 compute) must
    // keep exact top-1 agreement; the epsilon stays documented in the
    // JSON.  The full-int8 row is diagnostic: its noise floor is higher
    // (error injected per stage), so it is recorded, not gated.
    anyhow::ensure!(
        i8_top1 >= min_top1,
        "i8-wire top-1 agreement {i8_top1:.3} under floor {min_top1}"
    );
    anyhow::ensure!(i8_eps < 0.05, "i8-wire digest eps {i8_eps:.3} out of bounds");
    Ok(())
}
