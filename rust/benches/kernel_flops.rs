//! Kernel GFLOP/s bench for the CPU tensor compute backend: blocked
//! parallel GEMM vs the cache-naive reference at 256^3, an
//! SSD-Mobilenet-shaped conv (im2col + GEMM) and depthwise conv, each
//! at 1 / 2 / 4 workers.  Emits `BENCH_kernel_flops.json`.
//!
//! CI smoke assertions (see EXPERIMENTS.md "Kernel GFLOP/s" for the
//! methodology):
//! * blocked single-thread GEMM >= `EP_MIN_SPEEDUP`x naive (default 3)
//! * with >= 4 cores, 4-worker GEMM >= `EP_MIN_SCALING`x single-worker
//!   (default 1.3; 0 disables — CI runners advertise hyperthreads as
//!   cores, so the floor is tunable without editing the bench)
//!
//! Knobs: EP_GEMM_N (default 256), EP_ITERS (timed reps, default 5),
//! EP_MIN_SPEEDUP, EP_MIN_SCALING, EP_PIN (pin workers, default 0).

use edge_prune::benchkit::{env_or, header, stats, time_iters, write_bench_json};
use edge_prune::platform::affinity::core_count;
use edge_prune::runtime::linalg::{
    conv2d, dwconv2d, gemm, gemm_flops, gemm_naive, Conv2dSpec, ConvScratch, GemmScratch,
};
use edge_prune::util::json::Json;
use edge_prune::util::rng::Rng;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
}

fn gflops_of(flops: u64, ms_per_iter: f64) -> f64 {
    flops as f64 / (ms_per_iter * 1e6)
}

fn main() -> anyhow::Result<()> {
    let n: usize = env_or("EP_GEMM_N", 256usize);
    let iters: usize = env_or("EP_ITERS", 5usize);
    let min_speedup: f64 = env_or("EP_MIN_SPEEDUP", 3.0f64);
    let min_scaling: f64 = env_or("EP_MIN_SCALING", 1.3f64);
    let pin: bool = env_or("EP_PIN", 0usize) != 0;
    let workers_tiers = [1usize, 2, 4];
    let cores = core_count();

    header(&format!("kernel GFLOP/s (GEMM {n}^3, conv, depthwise; {cores} cores)"));
    println!("{:<26} {:>8} {:>10} {:>10}", "kernel", "workers", "ms/iter", "GFLOP/s");

    let mut rng = Rng::new(7);
    let mut rows: Vec<Json> = Vec::new();
    let mut push_row = |kernel: &str, workers: usize, ms: f64, flops: u64| -> f64 {
        let gf = gflops_of(flops, ms);
        println!("{kernel:<26} {workers:>8} {ms:>10.2} {gf:>10.2}");
        rows.push(Json::from_pairs(vec![
            ("kernel", Json::from(kernel)),
            ("workers", Json::from(workers)),
            ("ms_per_iter", Json::from(ms)),
            ("gflops", Json::from(gf)),
        ]));
        gf
    };

    // ---- GEMM n^3: naive baseline, then blocked at each worker tier.
    let a = randv(&mut rng, n * n);
    let b = randv(&mut rng, n * n);
    let mut c = vec![0.0f32; n * n];
    let flops = gemm_flops(n, n, n);

    let naive_ms = stats(&time_iters(1, iters, || gemm_naive(n, n, n, &a, &b, &mut c))).p50;
    let naive_gf = push_row("gemm_naive", 1, naive_ms, flops);

    let mut gemm_gf = Vec::new();
    for &w in &workers_tiers {
        let mut scratch = GemmScratch::new();
        let ms =
            stats(&time_iters(1, iters, || gemm(n, n, n, &a, &b, &mut c, w, pin, &mut scratch)))
                .p50;
        gemm_gf.push(push_row("gemm_blocked", w, ms, flops));
    }

    // ---- Conv: an SSD-Mobilenet middle shape (28x28x128, 3x3 same).
    let conv = Conv2dSpec {
        h: 28,
        w: 28,
        c_in: 128,
        c_out: 128,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
        relu: true,
    };
    let x = randv(&mut rng, conv.in_len());
    let wt = randv(&mut rng, conv.patch() * conv.c_out);
    let bias = randv(&mut rng, conv.c_out);
    let mut y = vec![0.0f32; conv.out_len()];
    for &w in &workers_tiers {
        let mut scratch = ConvScratch::new();
        let ms = stats(&time_iters(1, iters, || {
            conv2d(&conv, &x, &wt, Some(&bias), &mut y, &mut scratch, w)
        }))
        .p50;
        push_row("conv2d_im2col", w, ms, conv.flops());
    }

    // ---- Depthwise: the SSD-Mobilenet dw shape (56x56x128, 3x3 same).
    let dw = Conv2dSpec {
        h: 56,
        w: 56,
        c_in: 128,
        c_out: 128,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
        relu: true,
    };
    let dx = randv(&mut rng, dw.in_len());
    let dwt = randv(&mut rng, dw.kh * dw.kw * dw.c_in);
    let mut dy = vec![0.0f32; dw.out_len()];
    // Depthwise FLOPs: 2 * OH * OW * KH * KW * C (one MAC per tap/channel).
    let dw_flops = 2 * (dw.out_h() * dw.out_w() * dw.kh * dw.kw * dw.c_in) as u64;
    for &w in &workers_tiers {
        let ms = stats(&time_iters(1, iters, || {
            dwconv2d(&dw, &dx, &dwt, Some(&bias), &mut dy, w)
        }))
        .p50;
        push_row("dwconv2d_direct", w, ms, dw_flops);
    }

    let speedup = gemm_gf[0] / naive_gf.max(1e-9);
    let scaling = gemm_gf[gemm_gf.len() - 1] / gemm_gf[0].max(1e-9);
    println!(
        "blocked/naive speedup: {speedup:.2}x (floor {min_speedup}x); \
         4-worker scaling: {scaling:.2}x"
    );

    let out = Json::from_pairs(vec![
        ("bench", Json::from("kernel_flops")),
        ("gemm_n", Json::from(n)),
        ("iters", Json::from(iters)),
        ("cores", Json::from(cores)),
        ("blocked_over_naive", Json::from(speedup)),
        ("four_worker_scaling", Json::from(scaling)),
        ("rows", Json::Arr(rows)),
    ]);
    write_bench_json("kernel_flops", &out)?;

    anyhow::ensure!(
        speedup >= min_speedup,
        "blocked GEMM only {speedup:.2}x naive (floor {min_speedup}x)"
    );
    // Worker scaling needs real cores; skip the assert on small hosts
    // (the JSON still records the measured curve).
    if cores >= 4 && min_scaling > 0.0 {
        anyhow::ensure!(
            scaling >= min_scaling,
            "4-worker GEMM only {scaling:.2}x single-worker on {cores} cores \
             (floor {min_scaling}x)"
        );
    }
    Ok(())
}
