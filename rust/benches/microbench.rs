//! Microbenchmarks + ablations over the framework substrates: FIFO
//! throughput, engine scheduling overhead, XLA per-actor execution
//! latency, vision post-processing, JSON parsing — plus the DESIGN.md
//! ablations (FIFO capacity sweep, netsim on/off).
//!
//! These are the numbers the §Perf optimization pass tracks.

use edge_prune::benchkit::{header, stats, throughput, time_iters};
use edge_prune::dataflow::{AppGraph, Token};
use edge_prune::models::builder::{build_graph, make_kernels, KernelOptions};
use edge_prune::models::manifest::Manifest;
use edge_prune::runtime::device::DeviceModel;
use edge_prune::runtime::engine::Engine;
use edge_prune::runtime::fifo::Fifo;
use edge_prune::runtime::kernels::{ActorKernel, MapKernel, SinkKernel, SourceKernel};
use edge_prune::runtime::wire::WireDtype;
use edge_prune::runtime::xla_exec::{Variant, XlaService};
use edge_prune::util::json::Json;
use edge_prune::util::tensor;
use edge_prune::vision::anchors::gen_anchors;
use edge_prune::vision::nms::{detections_to_token, nms, Detection, MAX_DETS};
use edge_prune::vision::tracker::IouTracker;
use std::collections::BTreeMap;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

fn bench_fifo() {
    header("fifo: push/pop throughput (tokens/s)");
    for cap in [1usize, 4, 64] {
        let f = Arc::new(Fifo::new(cap));
        let n = 200_000usize;
        let f2 = f.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                f2.push(Token::new(Vec::new(), i as u64));
            }
            f2.close();
        });
        let (ms, tps) = throughput(n, || while f.pop_n(1).is_some() {});
        producer.join().unwrap();
        println!("  capacity {cap:>3}: {:.1} ms for {n} tokens = {:.2} Mtokens/s", ms, tps / 1e6);
    }
}

fn bench_engine_overhead() {
    header("engine: scheduling overhead per firing (3-actor chain, empty kernels)");
    let frames = 20_000u64;
    let mut g = AppGraph::new();
    let a = g.add_spa("src");
    let b = g.add_spa("mid");
    let c = g.add_spa("snk");
    g.connect(a, b, 8, 4);
    g.connect(b, c, 8, 4);
    let engine = Engine::new(g, DeviceModel::native("host")).unwrap();
    let nsk = Arc::new(AtomicU64::new(0));
    let mut kernels: BTreeMap<String, Box<dyn ActorKernel>> = BTreeMap::new();
    kernels.insert("src".into(), Box::new(SourceKernel::new(frames, 8, 1, 1)));
    kernels.insert("mid".into(), Box::new(MapKernel { f: |b: &[u8]| b.to_vec(), out_ports: 1 }));
    kernels.insert("snk".into(), Box::new(SinkKernel::new(nsk)));
    let t0 = std::time::Instant::now();
    let report = engine.run(kernels).unwrap();
    let us_per_firing = t0.elapsed().as_secs_f64() * 1e6 / (frames as f64 * 3.0);
    println!(
        "  {} frames x 3 actors in {:.1} ms -> {:.2} us/firing",
        report.frames,
        t0.elapsed().as_secs_f64() * 1e3,
        us_per_firing
    );
}

fn bench_xla(manifest: &Manifest) {
    header("xla_exec: per-actor execution latency (vehicle, jnp variant)");
    let meta = manifest.model("vehicle").unwrap();
    let svc = XlaService::spawn(&manifest.root, meta, Variant::Jnp).unwrap();
    for name in ["l1", "l2", "l3", "l45"] {
        let e = &meta.hlo_entries[name];
        let n: usize = e.in_shapes[0].iter().product();
        let input = tensor::f32_to_bytes(&vec![0.1f32; n]);
        let samples = time_iters(2, 10, || {
            svc.execute(name, vec![input.clone()]).unwrap();
        });
        let s = stats(&samples);
        println!("  {name:<5} p50 {:.2} ms  p95 {:.2} ms", s.p50, s.p95);
    }
}

fn bench_vision() {
    header("vision: anchors / NMS / tracker");
    let samples = time_iters(1, 10, || {
        let _ = gen_anchors(0, 19, 19, 3);
    });
    println!("  gen_anchors(19x19x3): p50 {:.3} ms", stats(&samples).p50);

    // NMS over the full SSD head: 1917 anchors x 21 classes.
    let n = 1917;
    let mut rng = edge_prune::util::rng::Rng::new(3);
    let scores: Vec<f32> = (0..n * 21).map(|_| rng.f32_range(0.0, 0.12)).collect();
    let boxes: Vec<f32> = (0..n)
        .flat_map(|_| {
            let x = rng.f32_range(0.0, 0.8);
            let y = rng.f32_range(0.0, 0.8);
            vec![x, y, x + 0.15, y + 0.15]
        })
        .collect();
    let samples = time_iters(1, 10, || {
        let _ = nms(&scores, &boxes, 21, 0.05, 0.5, MAX_DETS);
    });
    println!("  nms(1917x21): p50 {:.3} ms", stats(&samples).p50);

    let dets: Vec<Detection> = (0..20)
        .map(|i| Detection {
            class: 1 + i % 3,
            score: 0.5,
            bbox: [0.04 * i as f32, 0.04 * i as f32, 0.04 * i as f32 + 0.1, 0.04 * i as f32 + 0.1],
        })
        .collect();
    let token = detections_to_token(&dets, MAX_DETS);
    let mut tracker = IouTracker::new(0.3, 3);
    let samples = time_iters(1, 10, || {
        let d = edge_prune::vision::nms::token_to_detections(&token);
        tracker.update(&d);
    });
    println!("  tracker.update(20 dets): p50 {:.3} ms", stats(&samples).p50);
}

fn bench_json() {
    header("util::json: manifest parse");
    let text = std::fs::read_to_string(Manifest::default_dir().join("manifest.json")).unwrap();
    let samples = time_iters(1, 5, || {
        let _ = Json::parse(&text).unwrap();
    });
    println!(
        "  {} KiB manifest: p50 {:.2} ms",
        text.len() / 1024,
        stats(&samples).p50
    );
}

/// Ablation: FIFO capacity vs local pipeline throughput (pipelining depth).
fn ablation_capacity(manifest: &Manifest) {
    header("ablation: FIFO capacity vs vehicle local pipeline (native host)");
    let meta = manifest.model("vehicle").unwrap();
    let svc = XlaService::spawn(&manifest.root, meta, Variant::Jnp).unwrap();
    for cap in [1usize, 2, 4, 8] {
        let graph = build_graph(meta, cap).unwrap();
        let opts = KernelOptions { frames: 12, seed: 1, keep_last: false, ..Default::default() };
        let (kernels, _) = make_kernels(meta, &graph, &svc, &opts).unwrap();
        let engine = Engine::new(graph, DeviceModel::native("host")).unwrap();
        let report = engine.run(kernels).unwrap();
        println!("  capacity {cap}: {:.2} ms/frame", report.ms_per_frame());
    }
}

/// Ablation: netsim on/off at the Fig-4 PP3 cut (isolates the
/// communication share of endpoint time).
fn ablation_netsim(manifest: &Manifest) {
    use edge_prune::explorer::{sweep, SweepConfig};
    use edge_prune::platform::configs::Configs;
    use edge_prune::runtime::netsim::LinkModel;
    header("ablation: netsim on/off at vehicle PP3 (N2 endpoint)");
    let configs = Configs::load_default().unwrap();
    for (label, link, port) in [
        ("shaped eth", configs.link("n2_i7_eth").unwrap(), 29_000u16),
        ("ideal link", LinkModel::ideal(), 29_500u16),
    ] {
        let cfg = SweepConfig {
            model: "vehicle".into(),
            endpoint: configs.device("n2", "vehicle").unwrap(),
            server: configs.device("i7", "vehicle").unwrap(),
            link,
            frames: 12,
            pps: vec![3],
            base_port: port,
            variant: Variant::Jnp,
            time_scale: 4.0,
            seed: 2,
            wire: WireDtype::F32,
        };
        let report = sweep(manifest, &cfg).unwrap();
        println!("  {label}: {:.2} ms/frame", report.results[0].endpoint_ms);
    }
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    bench_fifo();
    bench_engine_overhead();
    bench_xla(&manifest);
    bench_vision();
    bench_json();
    ablation_capacity(&manifest);
    ablation_netsim(&manifest);
    Ok(())
}
