//! Fig. 5 — Vehicle classification endpoint inference time, N270 <-> i7.
//!
//! The single-core Atom N270 cannot overlap compute with transmission, so
//! the endpoint time is the *sum* of compute and TX serialization (vs the
//! N2's max).  Paper reference points: full endpoint 443 ms; raw offload
//! 28.6 ms (Ethernet) / 38.9 ms (WiFi); privacy-optimal PP2 (Input+L1 on
//! the endpoint) = 167 ms (Ethernet) / 191 ms (WiFi).
//! Env knobs: EP_FRAMES (default 8), EP_TIME_SCALE (1).

use edge_prune::benchkit::{env_or, header, row};
use edge_prune::explorer::{format_table, sweep, SweepConfig};
use edge_prune::models::manifest::Manifest;
use edge_prune::platform::configs::Configs;
use edge_prune::runtime::wire::WireDtype;
use edge_prune::runtime::xla_exec::Variant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let configs = Configs::load_default()?;
    let frames: u64 = env_or("EP_FRAMES", 8);
    let time_scale: f64 = env_or("EP_TIME_SCALE", 1.0);

    header("Fig. 5: vehicle classification, N270 endpoint <-> i7 server");
    let mut summaries = Vec::new();
    for (link_name, base_port) in [("n270_i7_eth", 22_000u16), ("n270_i7_wifi", 23_000u16)] {
        let cfg = SweepConfig {
            model: "vehicle".into(),
            endpoint: configs.device("n270", "vehicle")?,
            server: configs.device("i7", "vehicle")?,
            link: configs.link(link_name)?,
            frames,
            pps: (1..=6).collect(),
            base_port,
            variant: Variant::Jnp,
            time_scale,
            seed: 5,
            wire: WireDtype::F32,
        };
        let report = sweep(&manifest, &cfg)?;
        print!("{}", format_table(&report));
        summaries.push(report);
    }

    header("Fig. 5 paper-vs-measured checkpoints");
    let (eth, wifi) = (&summaries[0], &summaries[1]);
    let at = |r: &edge_prune::explorer::SweepReport, pp: usize| {
        r.results.iter().find(|x| x.pp == pp).map(|x| x.endpoint_ms).unwrap_or(f64::NAN)
    };
    println!("{}", row("full endpoint inference", 443.0, eth.full_endpoint_ms, "ms"));
    println!("{}", row("PP1 raw offload (Ethernet)", 28.6, at(eth, 1), "ms"));
    println!("{}", row("PP1 raw offload (WiFi)", 38.9, at(wifi, 1), "ms"));
    println!("{}", row("PP2 privacy-optimal (Ethernet)", 167.0, at(eth, 2), "ms"));
    println!("{}", row("PP2 privacy-optimal (WiFi)", 191.0, at(wifi, 2), "ms"));
    println!(
        "best privacy-preserving PP: paper=2, measured eth={:?} wifi={:?}",
        eth.best_private().map(|b| b.pp),
        wifi.best_private().map(|b| b.pp)
    );
    Ok(())
}
