//! §IV.C — Dual-input vehicle image classification across three devices.
//!
//! Paper reference: per-frame time 49 ms on the N270 (2nd Input only),
//! 154 ms on the N2 (Input..L3 of branch 1), 157 ms on the i7 server
//! (branch 2's L1..L3 + the two-input L4L5 join).
//! Env knobs: EP_FRAMES (default 16), EP_TIME_SCALE (4).

use edge_prune::benchkit::{env_or, header, row};
use edge_prune::compiler::compile;
use edge_prune::models::builder::{build_graph, KernelOptions, DEFAULT_CAPACITY};
use edge_prune::models::manifest::Manifest;
use edge_prune::models::vehicle::{dual_mapping, dual_meta};
use edge_prune::platform::configs::Configs;
use edge_prune::platform::PlatformGraph;
use edge_prune::runtime::distributed::run_deployment;
use edge_prune::runtime::xla_exec::{Variant, XlaService};
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let configs = Configs::load_default()?;
    let frames: u64 = env_or("EP_FRAMES", 16);
    let time_scale: f64 = env_or("EP_TIME_SCALE", 4.0);

    header("Sec IV.C: dual-input vehicle classification (N2 + N270 -> i7)");
    let meta = dual_meta(manifest.model("vehicle")?)?;
    let graph = build_graph(&meta, DEFAULT_CAPACITY)?;
    println!(
        "{} actors / {} edges; two Input..L3 branches joining at l45_dual",
        graph.actors.len(),
        graph.edges.len()
    );

    let mut devices = BTreeMap::new();
    for name in ["n2", "n270", "i7"] {
        let mut d = configs.device(name, "vehicle")?;
        d.time_scale = time_scale;
        devices.insert(name.to_string(), d);
    }
    let mut pg = PlatformGraph::new();
    for d in devices.values() {
        pg.add_device(d.clone());
    }
    pg.add_link("n2", "i7", configs.link("n2_i7_eth")?.scaled(time_scale));
    pg.add_link("n270", "i7", configs.link("n270_i7_eth")?.scaled(time_scale));

    let plan = compile(&graph, &pg, &dual_mapping(), 27_000)?;
    println!("compiler: {} TX/RX FIFO pairs", plan.cut_edges());

    let services: BTreeMap<String, XlaService> = ["n2", "n270", "i7"]
        .iter()
        .map(|d| {
            Ok((d.to_string(), XlaService::spawn(&manifest.root, &meta, Variant::Jnp)?))
        })
        .collect::<anyhow::Result<_>>()?;
    let opts = KernelOptions { frames, seed: 13, keep_last: false, ..Default::default() };
    let reports = run_deployment(&plan, &meta, &services, &devices, &opts)?;

    header("Sec IV.C paper-vs-measured");
    for (dev, paper) in [("n270", 49.0), ("n2", 154.0), ("i7", 157.0)] {
        let measured = reports
            .get(dev)
            .map(|r| r.ms_per_frame() / time_scale)
            .unwrap_or(f64::NAN);
        println!("{}", row(&format!("{dev} per-frame time"), paper, measured, "ms"));
    }
    println!(
        "join fired on every frame: {}",
        reports["i7"].actors.get("l45_dual").map(|s| s.firings).unwrap_or(0) == frames
    );
    println!(
        "note: the paper's absolute Sec IV.C numbers include join-\n\
         synchronization stalls it does not characterize; we reproduce the\n\
         configuration and report the ordering (N270 least loaded) + join\n\
         correctness. See EXPERIMENTS.md."
    );
    Ok(())
}
