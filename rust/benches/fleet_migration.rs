//! Fleet migration bench: two in-process servers ping-pong one live
//! sparse-wire session through rolling drains while the client keeps
//! inferring.  Measures the control-plane hand-off (quiesce + export +
//! peer mint + hint) and the client-side rebind (first inference after
//! a drain, including the redirect and RECONNECT), and proves the
//! availability story the fleet tentpole claims: zero inferences lost
//! across every migration.  Emits `BENCH_fleet.json`.
//!
//! CI smoke assertions (EXPERIMENTS.md "Rolling drain" has the
//! methodology):
//! * service availability across the whole run >= `EP_FLEET_MIN_AVAIL`
//!   (default 0.99; measured 1.0 — the replay ring makes every frame
//!   land exactly once even while its session changes servers);
//! * every drain actually moved the session (migrations followed ==
//!   rounds) and every frame completed (zero losses, zero local
//!   fallbacks);
//! * every response verifies against the sparse-codec ground truth, so
//!   the negotiated dtype demonstrably survives each move.
//!
//! Knobs: EP_ITERS (drain rounds, default 24), EP_FLEET_FRAMES (frames
//! between drains, default 8), EP_FLEET_MIN_AVAIL.

use edge_prune::benchkit::{env_or, header, write_bench_json};
use edge_prune::runtime::metrics::LatencyHistogram;
use edge_prune::runtime::wire::WireDtype;
use edge_prune::server::failover::{FailoverClient, FailoverConfig};
use edge_prune::server::model::{expected_digest_codec, make_input};
use edge_prune::server::{Server, ServerConfig};
use edge_prune::util::json::Json;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let rounds: u64 = env_or("EP_ITERS", 24);
    let frames_between: u64 = env_or("EP_FLEET_FRAMES", 8);
    let min_avail: f64 = env_or("EP_FLEET_MIN_AVAIL", 0.99);
    header("fleet migration: rolling-drain ping-pong between two servers");

    let cfg = ServerConfig { workers: 2, pin_workers: false, ..ServerConfig::default() };
    let servers = [Server::start(cfg.clone())?, Server::start(cfg)?];
    let addrs = [servers[0].addr().to_string(), servers[1].addr().to_string()];

    let pp = 2usize;
    let mut fc = FailoverClient::new(FailoverConfig {
        addr: addrs[0].clone(),
        pp,
        client_id: "fleet-bench".into(),
        wire: WireDtype::SparseI8,
        max_attempts: 3,
        reconnect_backoff: Duration::from_millis(1),
        ..FailoverConfig::default()
    });

    let drain_hist = LatencyHistogram::new();
    let rebind_hist = LatencyHistogram::new();
    let steady_hist = LatencyHistogram::new();
    let mut frame = 0u64;
    let mut verified = 0u64;
    let mut infer = |fc: &mut FailoverClient, hist: &LatencyHistogram| -> anyhow::Result<()> {
        let input = make_input(frame);
        let t0 = Instant::now();
        let (body, served) = fc.infer(&input)?;
        hist.record(t0.elapsed());
        anyhow::ensure!(!served.is_local(), "frame {frame} fell back to local");
        anyhow::ensure!(
            body == expected_digest_codec(&input, pp, fc.codec()),
            "frame {frame} digest mismatch after {verified} verified"
        );
        frame += 1;
        verified += 1;
        Ok(())
    };

    // Warm the session (plan compile, codec negotiation) off the clock.
    for _ in 0..4 {
        infer(&mut fc, &steady_hist)?;
    }

    for r in 0..rounds {
        for _ in 0..frames_between {
            infer(&mut fc, &steady_hist)?;
        }
        // Rolling drain: the owner quiesces and hands the session to
        // the other server, then rejoins the fleet — exactly the
        // `serve --drain-on` path minus the process exit.
        let owner = (r % 2) as usize;
        let t0 = Instant::now();
        let _ = servers[owner].drain_to(Some(&addrs[1 - owner]));
        drain_hist.record(t0.elapsed());
        servers[owner].resume_admissions();
        // First frame after the drain pays the redirect + RECONNECT.
        infer(&mut fc, &rebind_hist)?;
    }
    fc.finish();

    let stats = fc.stats();
    let avail = stats.service_availability();
    println!(
        "rounds {rounds}: drain p50 {:.2} ms p99 {:.2} ms | rebind p50 {:.2} ms p99 {:.2} ms | steady p50 {:.3} ms",
        drain_hist.quantile_ms(0.5),
        drain_hist.quantile_ms(0.99),
        rebind_hist.quantile_ms(0.5),
        rebind_hist.quantile_ms(0.99),
        steady_hist.quantile_ms(0.5),
    );
    println!(
        "availability {:.6} | {} frames verified | {} migrations followed",
        avail, verified, stats.migrations_followed
    );

    let out = Json::from_pairs(vec![
        ("rounds", Json::from(rounds)),
        ("frames_between_drains", Json::from(frames_between)),
        ("frames_verified", Json::from(verified)),
        ("availability", Json::from(avail)),
        ("migrations_followed", Json::from(stats.migrations_followed)),
        ("reconnects", Json::from(stats.reconnects)),
        ("drain_ms_p50", Json::from(drain_hist.quantile_ms(0.5))),
        ("drain_ms_p99", Json::from(drain_hist.quantile_ms(0.99))),
        ("rebind_ms_p50", Json::from(rebind_hist.quantile_ms(0.5))),
        ("rebind_ms_p99", Json::from(rebind_hist.quantile_ms(0.99))),
        ("steady_ms_p50", Json::from(steady_hist.quantile_ms(0.5))),
        ("steady_ms_p99", Json::from(steady_hist.quantile_ms(0.99))),
    ]);
    write_bench_json("fleet", &out)?;

    anyhow::ensure!(
        avail >= min_avail,
        "availability {avail:.4} under rolling drain below floor {min_avail}"
    );
    anyhow::ensure!(
        stats.migrations_followed == rounds,
        "only {} of {rounds} drains moved the session",
        stats.migrations_followed
    );
    anyhow::ensure!(
        stats.completed == stats.requested,
        "lost {} inferences",
        stats.requested - stats.completed
    );

    let [a, b] = servers;
    let ma = a.shutdown();
    let mb = b.shutdown();
    let moved_out = ma.get("sessions_migrated_out")?.int().unwrap_or(0)
        + mb.get("sessions_migrated_out")?.int().unwrap_or(0);
    anyhow::ensure!(
        moved_out == rounds as i64,
        "servers ledger {moved_out} exports, expected {rounds}"
    );
    Ok(())
}
