//! Table II — network characteristics: validates that the netsim link
//! conditioner reproduces each link's measured throughput and latency on
//! localhost TCP (the Table-I/II substitution's calibration certificate).
//!
//! For each link we stream messages through a shaped TX/RX FIFO pair and
//! report achieved MB/s + first-byte latency next to the paper's values.

use edge_prune::benchkit::{header, row, stats};
use edge_prune::dataflow::Token;
use edge_prune::platform::configs::Configs;
use edge_prune::runtime::kernels::{ActorKernel, FireOutcome};
use edge_prune::runtime::net::{bind_local, RxKernel, TxKernel};
use edge_prune::runtime::netsim::{LinkModel, LinkShaper};
use edge_prune::runtime::wire::WireDtype;
use std::time::{Duration, Instant};

fn measure(link: LinkModel, msg_bytes: usize, msgs: usize) -> anyhow::Result<(f64, f64)> {
    let listener = bind_local(0)?;
    let addr = listener.local_addr()?.to_string();
    let shaper = LinkShaper::new(link.clone());
    let rx_shaper = LinkShaper::new(link);
    let rx_h = std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
        let mut rx = RxKernel::accept(listener, rx_shaper, 1, WireDtype::F32)?;
        let mut latencies = Vec::new();
        loop {
            let t0 = Instant::now();
            match rx.fire(&[], 0)? {
                FireOutcome::Stop => break,
                FireOutcome::Produced(_) => {
                    latencies.push(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
        }
        Ok(latencies)
    });
    let mut tx = TxKernel::connect(&addr, shaper, Duration::from_secs(5), WireDtype::F32)?;
    let t0 = Instant::now();
    for i in 0..msgs {
        let tok = Token::new(vec![0u8; msg_bytes], i as u64);
        tx.fire(&[vec![tok]], i as u64)?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    drop(tx);
    let _lat = rx_h.join().unwrap()?;
    let mbytes_s = (msg_bytes * msgs) as f64 / elapsed / 1e6;
    // One-shot latency measurement: single small message on a fresh pair.
    Ok((mbytes_s, elapsed * 1e3))
}

fn measure_latency(link: LinkModel) -> anyhow::Result<f64> {
    let listener = bind_local(0)?;
    let addr = listener.local_addr()?.to_string();
    let shaper = LinkShaper::new(link.clone());
    let rx_shaper = LinkShaper::new(link);
    let rx_h = std::thread::spawn(move || -> anyhow::Result<Instant> {
        let mut rx = RxKernel::accept(listener, rx_shaper, 1, WireDtype::F32)?;
        let _ = rx.fire(&[], 0)?;
        Ok(Instant::now()) // delivery instant (after latency wait)
    });
    let mut tx = TxKernel::connect(&addr, shaper, Duration::from_secs(5), WireDtype::F32)?;
    std::thread::sleep(Duration::from_millis(20)); // let RX block first
    let t_send = Instant::now();
    tx.fire(&[vec![Token::new(vec![0u8; 64], 0)]], 0)?;
    drop(tx);
    let t_arrive = rx_h.join().unwrap()?;
    Ok(t_arrive.duration_since(t_send).as_secs_f64() * 1e3)
}

fn main() -> anyhow::Result<()> {
    let configs = Configs::load_default()?;
    header("Table II: network characteristics (netsim on localhost TCP)");
    println!(
        "{:<16} {:>10} {:>14} {:>14} {:>12} {:>12}",
        "link", "nominal", "paper-MB/s", "measured-MB/s", "paper-lat", "measured-lat"
    );
    for nom in configs.nominal_links()? {
        let link = LinkModel::new(&nom.name, nom.throughput_mbytes_s, nom.latency_ms);
        let (mbytes_s, _) = measure(link.clone(), 128 * 1024, 24)?;
        let lats: Vec<f64> = (0..5)
            .map(|_| measure_latency(link.clone()))
            .collect::<anyhow::Result<_>>()?;
        let lat = stats(&lats).p50;
        println!(
            "{:<16} {:>7.0}Mbit {:>14.1} {:>14.1} {:>10.2}ms {:>10.2}ms",
            nom.name, nom.bandwidth_mbit_s, nom.throughput_mbytes_s, mbytes_s,
            nom.latency_ms, lat
        );
    }
    header("Table II checkpoints");
    let eth = LinkModel::new("n2_i7_eth", 11.2, 1.49);
    let (mb, _) = measure(eth, 128 * 1024, 24)?;
    println!("{}", row("n2-i7 Ethernet throughput", 11.2, mb, "MB/s"));
    println!(
        "note: measured latency includes the RX blocking-read dispatch; the\n\
         shaper enforces >= configured one-way latency per message."
    );
    Ok(())
}
