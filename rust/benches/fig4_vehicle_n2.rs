//! Fig. 4 — Vehicle classification endpoint inference time, N2 <-> i7, at
//! every partition point, over Ethernet and WiFi.
//!
//! Paper reference points: full endpoint 18.9 ms; Ethernet PP1 (raw
//! offload) 9.0 ms; best privacy-preserving cut PP3 = 14.9 ms (Ethernet)
//! / 17.1 ms (WiFi); raw offload on WiFi is slower than full-endpoint
//! inference.  Env knobs: EP_FRAMES (default 24), EP_TIME_SCALE (4).

use edge_prune::benchkit::{env_or, header, row};
use edge_prune::explorer::{format_table, sweep, SweepConfig};
use edge_prune::models::manifest::Manifest;
use edge_prune::platform::configs::Configs;
use edge_prune::runtime::wire::WireDtype;
use edge_prune::runtime::xla_exec::Variant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let configs = Configs::load_default()?;
    let frames: u64 = env_or("EP_FRAMES", 24);
    let time_scale: f64 = env_or("EP_TIME_SCALE", 4.0);

    header("Fig. 4: vehicle classification, N2 endpoint <-> i7 server");
    let mut summaries = Vec::new();
    for (link_name, base_port) in [("n2_i7_eth", 20_000u16), ("n2_i7_wifi", 21_000u16)] {
        let cfg = SweepConfig {
            model: "vehicle".into(),
            endpoint: configs.device("n2", "vehicle")?,
            server: configs.device("i7", "vehicle")?,
            link: configs.link(link_name)?,
            frames,
            pps: (1..=6).collect(),
            base_port,
            variant: Variant::Jnp,
            time_scale,
            seed: 4,
            wire: WireDtype::F32,
        };
        let report = sweep(&manifest, &cfg)?;
        print!("{}", format_table(&report));
        summaries.push((link_name, report));
    }

    header("Fig. 4 paper-vs-measured checkpoints");
    let (eth, wifi) = (&summaries[0].1, &summaries[1].1);
    let at = |r: &edge_prune::explorer::SweepReport, pp: usize| {
        r.results.iter().find(|x| x.pp == pp).map(|x| x.endpoint_ms).unwrap_or(f64::NAN)
    };
    println!("{}", row("full endpoint inference", 18.9, eth.full_endpoint_ms, "ms"));
    println!("{}", row("PP1 raw offload (Ethernet)", 9.0, at(eth, 1), "ms"));
    println!("{}", row("PP3 privacy-optimal (Ethernet)", 14.9, at(eth, 3), "ms"));
    println!("{}", row("PP3 privacy-optimal (WiFi)", 17.1, at(wifi, 3), "ms"));
    let wifi_pp1 = at(wifi, 1);
    println!(
        "WiFi raw offload slower than full endpoint: paper=yes, measured={} ({:.1} vs {:.1} ms)",
        wifi_pp1 > eth.full_endpoint_ms,
        wifi_pp1,
        eth.full_endpoint_ms
    );
    let best = eth.best_private().map(|b| b.pp);
    println!("best privacy-preserving PP on Ethernet: paper=3, measured={best:?}");
    Ok(())
}
