//! Flight-recorder overhead bench: end-to-end serving throughput with
//! tracing fully on (every request traced, client + server spans) vs
//! tracing disabled at runtime, on the same in-process server/loadgen
//! pair.  Emits `BENCH_trace_overhead.json`.
//!
//! CI smoke assertion (EXPERIMENTS.md "Trace overhead" has the
//! methodology): the traced wave keeps throughput within
//! `EP_MAX_OVERHEAD_PCT` percent of the untraced wave (default 5).
//! Waves are interleaved and the best of `EP_TRIALS` is compared on
//! each side so scheduler noise doesn't masquerade as tracing cost.
//!
//! Knobs: EP_CLIENTS (4), EP_REQUESTS (per client, 300), EP_TRIALS (3),
//! EP_MAX_OVERHEAD_PCT (5).

use edge_prune::benchkit::{env_or, header, write_bench_json};
use edge_prune::runtime::trace;
use edge_prune::server::loadgen::{run_loadgen, LoadgenConfig};
use edge_prune::server::{Server, ServerConfig};
use edge_prune::util::json::Json;

/// One full serve + loadgen wave; returns achieved req/s.  Tracing is a
/// process-global toggle, so each wave resets it on the way out — the
/// disabled wave must really run with the recorder off and drained.
fn run_wave(traced: bool, clients: usize, requests: u64) -> anyhow::Result<f64> {
    let server = Server::start(ServerConfig {
        trace: traced,
        workers: 4,
        // Shared machines: the comparison wants identical scheduling on
        // both sides, not exclusive cores.
        pin_workers: false,
        ..ServerConfig::default()
    })?;
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients,
        requests,
        pp: 3,
        seed: 42,
        trace: traced,
        ..LoadgenConfig::default()
    })?;
    server.shutdown();
    trace::set_enabled(false);
    let spans = trace::drain();
    anyhow::ensure!(
        report.errors == 0 && report.lost() == 0,
        "wave lost work (traced={traced}): {}",
        report.summary()
    );
    if traced && cfg!(feature = "trace") {
        anyhow::ensure!(
            report.traced == report.sent,
            "only {}/{} requests traced at sample 1",
            report.traced,
            report.sent
        );
        anyhow::ensure!(!spans.is_empty(), "traced wave recorded no spans");
    }
    Ok(report.requests_per_sec())
}

fn main() -> anyhow::Result<()> {
    let clients: usize = env_or("EP_CLIENTS", 4usize);
    let requests: u64 = env_or("EP_REQUESTS", 300u64);
    let trials: usize = env_or("EP_TRIALS", 3usize);
    let max_overhead: f64 = env_or("EP_MAX_OVERHEAD_PCT", 5.0f64);

    header(&format!(
        "trace overhead: {clients} clients x {requests} req, best of {trials} \
         (trace feature compiled: {})",
        cfg!(feature = "trace")
    ));

    // Warmup wave so thread spawn / page faults don't land in trial 1.
    run_wave(false, clients, requests.min(64))?;

    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    for trial in 0..trials {
        let off = run_wave(false, clients, requests)?;
        let on = run_wave(true, clients, requests)?;
        println!("trial {trial}: disabled {off:>8.0} req/s, traced {on:>8.0} req/s");
        best_off = best_off.max(off);
        best_on = best_on.max(on);
    }
    let overhead = (best_off - best_on) / best_off.max(1e-9) * 100.0;
    println!(
        "best: disabled {best_off:.0} req/s, traced {best_on:.0} req/s \
         -> {overhead:+.2}% overhead (ceiling {max_overhead}%)"
    );

    let out = Json::from_pairs(vec![
        ("bench", Json::from("trace_overhead")),
        ("clients", Json::from(clients)),
        ("requests", Json::from(requests)),
        ("trials", Json::from(trials)),
        ("trace_compiled", Json::from(cfg!(feature = "trace"))),
        ("rps_disabled", Json::from(best_off)),
        ("rps_traced", Json::from(best_on)),
        ("overhead_pct", Json::from(overhead)),
    ]);
    write_bench_json("trace_overhead", &out)?;

    anyhow::ensure!(
        overhead < max_overhead,
        "tracing costs {overhead:.2}% throughput (ceiling {max_overhead}%)"
    );
    Ok(())
}
