//! Overload shedding bench: the same deadline-carrying wave at ~2x the
//! server's comfortable concurrency, against a no-shedding baseline and
//! the EWMA shedding admission controller.  Measures goodput (verified
//! completions per second — `LoadReport::requests_per_sec`) and the
//! admitted-work p99, and proves the overload-control claims: every
//! non-admitted request is an explicit SHED or DEADLINE_EXCEEDED (zero
//! lost in both configs), and shedding beats the baseline's goodput by
//! refusing infeasible work at admission instead of letting it expire
//! in the queue.  Emits `BENCH_overload.json`.
//!
//! The deadline is calibrated, not hard-coded: a plain wave at the same
//! concurrency measures the loaded p50, and the overload waves then run
//! with that p50 as their budget — so roughly half the baseline's
//! admitted work expires after burning queue time, on any machine.
//!
//! CI smoke assertions (EXPERIMENTS.md "Overload wave" has the
//! methodology):
//! * both waves: zero lost — ok + rejected + shed + deadline-exceeded
//!   covers every request sent;
//! * the baseline (shedding off) sheds nothing, the shedding config
//!   sheds something;
//! * shedding goodput >= baseline goodput x `EP_OVERLOAD_MIN_RATIO`
//!   (default 1.0 — shedding must not lose);
//! * admitted p99 under shedding <= `EP_OVERLOAD_P99_X` x the deadline
//!   budget (default 2.0) — the controller keeps admitted work inside
//!   its feasibility bound instead of queueing it to the edge.
//!
//! Knobs: EP_CLIENTS (default 16), EP_REQUESTS (per client, default
//! 150), EP_OVERLOAD_MIN_RATIO, EP_OVERLOAD_P99_X.

use edge_prune::benchkit::{env_or, header, write_bench_json};
use edge_prune::server::loadgen::{run_loadgen, LoadgenConfig, LoadReport};
use edge_prune::server::{Server, ServerConfig};
use edge_prune::util::json::Json;

fn overload_cfg(shed_delay_ms: f64) -> ServerConfig {
    ServerConfig {
        // One worker, small batches: the wave below is genuinely past
        // capacity, whatever the host machine.
        workers: 1,
        pin_workers: false,
        max_batch: 2,
        shed_delay_ms,
        ..ServerConfig::default()
    }
}

fn run_wave(
    server: &Server,
    clients: usize,
    requests: u64,
    deadline_ms: u64,
    seed: u64,
) -> anyhow::Result<LoadReport> {
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        clients,
        requests,
        pp: 2,
        deadline_ms,
        priority: 0,
        seed,
        ..LoadgenConfig::default()
    })?;
    // The explicitness contract holds in every configuration: a request
    // that was not served was refused out loud.
    anyhow::ensure!(report.errors == 0, "response errors under overload: {}", report.summary());
    anyhow::ensure!(report.lost() == 0, "lost requests under overload: {}", report.summary());
    anyhow::ensure!(
        report.ok + report.rejected + report.shed + report.deadline_exceeded == report.sent,
        "unaccounted outcomes: {}",
        report.summary()
    );
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    let clients: usize = env_or("EP_CLIENTS", 16usize);
    let requests: u64 = env_or("EP_REQUESTS", 150u64);
    let min_ratio: f64 = env_or("EP_OVERLOAD_MIN_RATIO", 1.0);
    let p99_x: f64 = env_or("EP_OVERLOAD_P99_X", 2.0);
    header(&format!(
        "overload shedding: {clients} deadline clients x {requests} req, \
         no-shedding baseline vs EWMA admission"
    ));

    // Calibrate: the loaded p50 at this concurrency, no deadlines.
    let server = Server::start(overload_cfg(0.0))?;
    let calib = run_wave(&server, clients, requests.min(60), 0, 77)?;
    server.shutdown();
    let p50 = calib.latency.quantile_ms(0.5);
    let deadline_ms = (p50.ceil() as u64).max(2);
    println!("calibration: loaded p50 {p50:.2} ms -> deadline budget {deadline_ms} ms");

    // Baseline: deadlines enforced, shedding off — infeasible work is
    // only discovered once its budget is gone.
    let server = Server::start(overload_cfg(0.0))?;
    let base = run_wave(&server, clients, requests, deadline_ms, 78)?;
    let base_metrics = server.shutdown();
    anyhow::ensure!(base.shed == 0, "baseline shed with shedding disabled");

    // Shedding: the queue-wait EWMA refuses infeasible work at
    // admission, while its budget is still alive.
    let server = Server::start(overload_cfg((p50 / 4.0).max(0.05)))?;
    let shed = run_wave(&server, clients, requests, deadline_ms, 79)?;
    let shed_metrics = server.shutdown();

    let base_goodput = base.requests_per_sec();
    let shed_goodput = shed.requests_per_sec();
    let shed_p99 = shed.latency.quantile_ms(0.99);
    println!("config     goodput/s     ok   shed   ddl-exceeded   admitted-p99-ms");
    for (name, r) in [("baseline", &base), ("shedding", &shed)] {
        println!(
            "{name:<10} {:>9.0} {:>6} {:>6} {:>14} {:>17.2}",
            r.requests_per_sec(),
            r.ok,
            r.shed,
            r.deadline_exceeded,
            r.latency.quantile_ms(0.99),
        );
    }

    let out = Json::from_pairs(vec![
        ("clients", Json::from(clients as u64)),
        ("requests_per_client", Json::from(requests)),
        ("deadline_ms", Json::from(deadline_ms)),
        ("calibrated_p50_ms", Json::from(p50)),
        ("baseline_goodput_rps", Json::from(base_goodput)),
        ("baseline_ok", Json::from(base.ok)),
        ("baseline_deadline_exceeded", Json::from(base.deadline_exceeded)),
        ("baseline_admitted_p99_ms", Json::from(base.latency.quantile_ms(0.99))),
        ("shed_goodput_rps", Json::from(shed_goodput)),
        ("shed_ok", Json::from(shed.ok)),
        ("shed_shed", Json::from(shed.shed)),
        ("shed_deadline_exceeded", Json::from(shed.deadline_exceeded)),
        ("shed_admitted_p99_ms", Json::from(shed_p99)),
        (
            "server_queue_delay_ewma_ms",
            Json::from(shed_metrics.get("queue_delay_ewma_ms")?.num()?),
        ),
        ("server_requests_shed", Json::from(shed_metrics.get("requests_shed")?.int()?)),
    ]);
    write_bench_json("overload", &out)?;

    // The server-side ledgers must agree with the clients': strict
    // loadgen clients never re-offer a shed request, so both counters
    // see each refusal exactly once.
    anyhow::ensure!(
        shed_metrics.get("requests_shed")?.int()? == shed.shed as i64,
        "server/client shed ledgers disagree"
    );
    anyhow::ensure!(
        base_metrics.get("requests_shed")?.int()? == 0,
        "baseline server shed with shedding disabled"
    );
    anyhow::ensure!(shed.shed > 0, "shedding config never shed under 2x overload");
    anyhow::ensure!(
        shed_goodput >= base_goodput * min_ratio,
        "shedding goodput {shed_goodput:.0}/s below baseline {base_goodput:.0}/s x {min_ratio}"
    );
    anyhow::ensure!(
        shed_p99 <= (deadline_ms as f64) * p99_x,
        "admitted p99 {shed_p99:.2} ms exceeds {p99_x}x the {deadline_ms} ms budget"
    );
    Ok(())
}
