//! §IV.D — Single-input end-to-end latency with a feedback socket.
//!
//! Paper reference: 31.2 ms end-to-end from endpoint data input to the
//! classification result on the edge server (signalled back over the
//! feedback connection), split 57% endpoint inference / 23% Ethernet
//! communication / 20% server inference; single-image inference is slower
//! than streaming (Fig. 4) because the pipeline never fills.
//! Env knobs: EP_REPEATS (default 5), EP_TIME_SCALE (4).

use edge_prune::benchkit::{env_or, header, row, stats};
use edge_prune::compiler::compile;
use edge_prune::explorer::precedence_order;
use edge_prune::models::builder::{build_graph, KernelOptions, DEFAULT_CAPACITY};
use edge_prune::models::manifest::{EdgeMeta, Manifest};
use edge_prune::platform::configs::Configs;
use edge_prune::platform::{Mapping, PlatformGraph};
use edge_prune::runtime::distributed::run_deployment;
use edge_prune::runtime::xla_exec::{Variant, XlaService};
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let configs = Configs::load_default()?;
    let repeats: usize = env_or("EP_REPEATS", 5);
    let time_scale: f64 = env_or("EP_TIME_SCALE", 4.0);

    header("Sec IV.D: single-image end-to-end latency with feedback socket");
    let mut meta = manifest.model("vehicle")?.clone();
    meta.actors.push("feedback".to_string());
    meta.edges.push(EdgeMeta { src: "l45".into(), dst: "feedback".into(), bytes: 16 });
    let graph = build_graph(&meta, DEFAULT_CAPACITY)?;
    let order = precedence_order(&meta)?;

    let mut n2 = configs.device("n2", "vehicle")?;
    let mut i7 = configs.device("i7", "vehicle")?;
    n2.time_scale = time_scale;
    i7.time_scale = time_scale;
    let mut mapping = Mapping::new();
    for a in &order {
        mapping.assign(
            a,
            if ["input", "l1", "l2", "feedback"].contains(&a.as_str()) { "n2" } else { "i7" },
        );
    }
    let mut pg = PlatformGraph::new();
    pg.add_device(n2.clone());
    pg.add_device(i7.clone());
    pg.add_link("n2", "i7", configs.link("n2_i7_eth")?.scaled(time_scale));

    let svc_e = XlaService::spawn(&manifest.root, &meta, Variant::Jnp)?;
    let svc_s = XlaService::spawn(&manifest.root, &meta, Variant::Jnp)?;
    let services: BTreeMap<String, XlaService> =
        [("n2".to_string(), svc_e), ("i7".to_string(), svc_s)].into_iter().collect();
    let devices: BTreeMap<String, _> =
        [("n2".to_string(), n2), ("i7".to_string(), i7)].into_iter().collect();

    let mut e2e = Vec::new();
    let mut ep = Vec::new();
    let mut srv = Vec::new();
    for rep in 0..repeats {
        let plan = compile(&graph, &pg, &mapping, 28_000 + rep as u16 * 50)?;
        let opts = KernelOptions { frames: 1, seed: 70 + rep as u64, keep_last: false, ..Default::default() };
        let reports = run_deployment(&plan, &meta, &services, &devices, &opts)?;
        e2e.push(reports["n2"].wall.as_secs_f64() * 1e3 / time_scale);
        let busy = |r: &edge_prune::runtime::metrics::RunReport, names: &[&str]| {
            names
                .iter()
                .filter_map(|n| r.actors.get(*n))
                .map(|s| s.busy.as_secs_f64() * 1e3)
                .sum::<f64>()
                / time_scale
        };
        ep.push(busy(&reports["n2"], &["input", "l1", "l2"]));
        srv.push(busy(&reports["i7"], &["l3", "l45"]));
    }
    let (e2e_s, ep_s, srv_s) = (stats(&e2e), stats(&ep), stats(&srv));
    let comm = (e2e_s.p50 - ep_s.p50 - srv_s.p50).max(0.0);

    header("Sec IV.D paper-vs-measured (median over repeats)");
    println!("{}", row("end-to-end latency", 31.2, e2e_s.p50, "ms"));
    println!("{}", row("endpoint inference (57%)", 17.5, ep_s.p50, "ms"));
    println!("{}", row("communication (23%)", 7.3, comm, "ms"));
    println!("{}", row("server inference (20%)", 6.3, srv_s.p50, "ms"));
    println!(
        "shares: endpoint {:.0}% / comm {:.0}% / server {:.0}%  (paper 57/23/20)",
        ep_s.p50 / e2e_s.p50 * 100.0,
        comm / e2e_s.p50 * 100.0,
        srv_s.p50 / e2e_s.p50 * 100.0
    );
    println!(
        "single-image > streaming per-frame (paper's cache remark): {:.1} ms vs 14.9 ms",
        e2e_s.p50
    );
    Ok(())
}
