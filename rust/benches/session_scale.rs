//! Session-scale bench: p99 latency vs concurrent session count
//! (64 / 256 / 512) against the event-driven server, asserting the
//! fixed-thread-inventory property along the way (OS thread count stays
//! a small constant while sessions grow 8x).
//!
//! Emits `BENCH_session_scale.json` for CI/EXPERIMENTS tracking.
//!
//! Knobs: EP_ROUNDS (requests per session), EP_PP (partition point),
//! EP_WORKERS (worker threads; default 4 so the thread budget is
//! deterministic), EP_SESSIONS (comma-free max tier override).

use edge_prune::benchkit::{env_or, header, write_bench_json};
use edge_prune::platform::procinfo::{ensure_fd_headroom, os_thread_count};
use edge_prune::server::loadgen::{run_session_wave, WaveConfig};
use edge_prune::server::{Server, ServerConfig};
use edge_prune::util::json::Json;

fn main() -> anyhow::Result<()> {
    let rounds: u64 = env_or("EP_ROUNDS", 4u64);
    let pp: usize = env_or("EP_PP", 2usize);
    let workers: usize = env_or("EP_WORKERS", 4usize);
    let max_tier: usize = env_or("EP_SESSIONS", 512usize);

    // 512 sessions need ~1100 fds in this process (server + client
    // ends); raise the soft limit and scale tiers to what we got.
    let headroom = ensure_fd_headroom(2 * max_tier as u64 + 256)?;
    let tiers: Vec<usize> = [64usize, 256, 512]
        .into_iter()
        .filter(|&s| s <= max_tier && 2 * s as u64 + 64 <= headroom)
        .collect();
    anyhow::ensure!(!tiers.is_empty(), "fd headroom {headroom} too small for any tier");

    header(&format!(
        "session scale: p99 vs concurrent sessions (pp {pp}, {rounds} req/session, \
         {workers} workers)"
    ));
    println!("sessions   req/s   p50-ms   p95-ms   p99-ms   os-threads");

    let mut rows: Vec<Json> = Vec::new();
    for &sessions in &tiers {
        let server = Server::start(ServerConfig {
            workers,
            pin_workers: false,
            max_sessions: sessions + 8,
            max_queue: 4 * sessions.max(256),
            ..ServerConfig::default()
        })?;
        let report = run_session_wave(&WaveConfig {
            addr: server.addr().to_string(),
            sessions,
            rounds,
            pp,
            seed: 42,
            ..WaveConfig::default()
        })?;
        anyhow::ensure!(report.errors == 0, "response errors at {sessions} sessions");
        anyhow::ensure!(report.ok == sessions as u64 * rounds, "lost work at {sessions}");
        // This process runs only the bench main thread + the server's
        // threads, so the OS count measures the real inventory: it must
        // match the declared budget (+1 for main, +1 slack), not just
        // stay under 16 — a regression that spawns per-session threads
        // fails here even if thread_count()'s arithmetic was updated.
        let os_threads = os_thread_count().unwrap_or(0);
        anyhow::ensure!(
            os_threads == 0 || os_threads < 16,
            "thread budget blown: {os_threads} OS threads at {sessions} sessions"
        );
        anyhow::ensure!(
            os_threads == 0 || os_threads <= server.thread_count() + 2,
            "{os_threads} OS threads exceed the declared inventory of {} (+main)",
            server.thread_count()
        );
        let rps = report.ok as f64 / report.wall.as_secs_f64().max(1e-9);
        let (p50, p95, p99) = (
            report.latency.quantile_ms(0.50),
            report.latency.quantile_ms(0.95),
            report.latency.quantile_ms(0.99),
        );
        println!(
            "{sessions:>8} {rps:>7.0} {p50:>8.2} {p95:>8.2} {p99:>8.2} {os_threads:>12}"
        );
        rows.push(Json::from_pairs(vec![
            ("sessions", Json::from(sessions)),
            ("ok", Json::from(report.ok)),
            ("requests_per_sec", Json::from(rps)),
            ("p50_ms", Json::from(p50)),
            ("p95_ms", Json::from(p95)),
            ("p99_ms", Json::from(p99)),
            ("os_threads", Json::from(os_threads)),
            ("server_threads", Json::from(server.thread_count())),
        ]));
        server.shutdown();
    }

    let out = Json::from_pairs(vec![
        ("bench", Json::from("session_scale")),
        ("workers", Json::from(workers)),
        ("rounds", Json::from(rounds)),
        ("pp", Json::from(pp)),
        ("rows", Json::Arr(rows)),
    ]);
    write_bench_json("session_scale", &out)?;
    Ok(())
}
