//! Session-scale bench: throughput vs reactor core count (1 / 2 / 4)
//! at a fixed large session population, proving the thread-per-core
//! server actually scales — and that the round-robin acceptor spreads
//! sessions evenly across shards (per-core load within 25% of mean).
//!
//! Emits `BENCH_session_scale.json` with per-core session / inference
//! counts for CI/EXPERIMENTS tracking.
//!
//! Knobs: EP_SESSIONS (total concurrent sessions, default 4096; scaled
//! down to fd headroom), EP_ROUNDS (requests per session), EP_PP
//! (partition point), EP_WORKERS (workers *per shard*, default 1 so
//! the core count is the parallelism axis), EP_MIN_SCALING (required
//! 4-core vs 1-core speedup on >=4-core hosts, default 1.5).

use std::sync::Arc;

use edge_prune::benchkit::{env_or, header, write_bench_json};
use edge_prune::platform::affinity::core_count;
use edge_prune::platform::procinfo::{ensure_fd_headroom, os_thread_count};
use edge_prune::runtime::metrics::LatencyHistogram;
use edge_prune::server::loadgen::{run_session_wave, WaveConfig, WaveReport};
use edge_prune::server::{Server, ServerConfig};
use edge_prune::util::json::Json;

/// Parallel wave-driver threads per tier.  Sessions are split evenly;
/// 4 drivers keep the client side from being the bottleneck at high
/// core counts without drowning a small host.
const WAVES: usize = 4;

fn main() -> anyhow::Result<()> {
    let rounds: u64 = env_or("EP_ROUNDS", 4u64);
    let pp: usize = env_or("EP_PP", 2usize);
    let workers: usize = env_or("EP_WORKERS", 1usize);
    let want_sessions: usize = env_or("EP_SESSIONS", 4096usize);
    let min_scaling: f64 = env_or("EP_MIN_SCALING", 1.5f64);

    // Each held-open session costs ~2 fds in this process (server +
    // client ends).  Raise the soft limit, then scale the population
    // to what we actually got, keeping it a multiple of WAVES * 4 so
    // every wave thread and every shard tier divides it exactly.
    let headroom = ensure_fd_headroom(2 * want_sessions as u64 + 512)?;
    let cap = (headroom.saturating_sub(512) / 2) as usize;
    let sessions = want_sessions.min(cap) / (WAVES * 4) * (WAVES * 4);
    anyhow::ensure!(sessions > 0, "fd headroom {headroom} too small for any session tier");

    let host_cores = core_count();
    header(&format!(
        "session scale: {sessions} sessions vs core count (pp {pp}, {rounds} req/session, \
         {workers} worker/shard, host has {host_cores} cores)"
    ));
    println!("cores   req/s   infer-ms   p50-ms   p95-ms   p99-ms   os-threads   spread");

    let mut rows: Vec<Json> = Vec::new();
    let mut throughput: Vec<(usize, f64)> = Vec::new();
    for cores in [1usize, 2, 4] {
        let server = Server::start(ServerConfig {
            cores,
            // Round-robin accept gives a deterministic shard spread,
            // which is what the 25%-of-mean assert below relies on.
            accept_rr: true,
            workers,
            pin_workers: false,
            max_sessions: sessions + 16,
            max_queue: 4 * sessions.max(256),
            ..ServerConfig::default()
        })?;
        anyhow::ensure!(server.cores() == cores, "server came up with wrong shard count");

        let per_wave = sessions / WAVES;
        let addr = server.addr().to_string();
        let handles: Vec<std::thread::JoinHandle<anyhow::Result<WaveReport>>> = (0..WAVES)
            .map(|w| {
                let cfg = WaveConfig {
                    addr: addr.clone(),
                    sessions: per_wave,
                    rounds,
                    pp,
                    seed: 42 + w as u64,
                    tag: format!("w{w}"),
                    ..WaveConfig::default()
                };
                std::thread::spawn(move || run_session_wave(&cfg))
            })
            .collect();
        let mut ok = 0u64;
        let mut infer_wall = std::time::Duration::ZERO;
        let latency = Arc::new(LatencyHistogram::new());
        for h in handles {
            let report = h.join().expect("wave thread panicked")?;
            anyhow::ensure!(report.errors == 0, "response errors at {cores} cores");
            ok += report.ok;
            infer_wall = infer_wall.max(report.infer_wall);
            latency.merge_from(&report.latency);
        }
        anyhow::ensure!(ok == sessions as u64 * rounds, "lost work at {cores} cores");

        // Wave threads are joined, so the OS count is bench main + the
        // server's declared inventory; a regression that spawns
        // per-session threads fails here even if thread_count()'s
        // arithmetic was updated to match.
        let os_threads = os_thread_count().unwrap_or(0);
        anyhow::ensure!(
            os_threads == 0 || os_threads <= server.thread_count() + 2,
            "{os_threads} OS threads exceed the declared inventory of {} (+main)",
            server.thread_count()
        );

        // Per-shard load: with round-robin accept and sessions % cores
        // == 0 the session spread is exact; inference completions may
        // wobble with scheduling, so the 25% band is checked on both.
        let loads = server.shard_loads();
        anyhow::ensure!(loads.len() == cores, "shard_loads returned {} shards", loads.len());
        let mut spread = 0.0f64;
        for (what, vals) in [
            ("sessions", loads.iter().map(|l| l.0).collect::<Vec<u64>>()),
            ("inferences", loads.iter().map(|l| l.1).collect::<Vec<u64>>()),
        ] {
            let mean = vals.iter().sum::<u64>() as f64 / cores as f64;
            for (shard, &v) in vals.iter().enumerate() {
                let dev = (v as f64 - mean).abs() / mean.max(1e-9);
                spread = spread.max(dev);
                anyhow::ensure!(
                    dev <= 0.25,
                    "{what} skew on shard {shard}: {v} vs mean {mean:.1} ({:.0}% off)",
                    dev * 100.0
                );
            }
        }

        let rps = ok as f64 / infer_wall.as_secs_f64().max(1e-9);
        let (p50, p95, p99) = (
            latency.quantile_ms(0.50),
            latency.quantile_ms(0.95),
            latency.quantile_ms(0.99),
        );
        let infer_ms = infer_wall.as_secs_f64() * 1e3;
        println!(
            "{cores:>5} {rps:>7.0} {infer_ms:>10.1} {p50:>8.2} {p95:>8.2} {p99:>8.2} \
             {os_threads:>12} {:>6.0}%",
            spread * 100.0
        );
        let per_core: Vec<Json> = loads
            .iter()
            .enumerate()
            .map(|(shard, &(admitted, completed))| {
                Json::from_pairs(vec![
                    ("shard", Json::from(shard)),
                    ("sessions", Json::from(admitted)),
                    ("inferences", Json::from(completed)),
                ])
            })
            .collect();
        rows.push(Json::from_pairs(vec![
            ("cores", Json::from(cores)),
            ("sessions", Json::from(sessions)),
            ("ok", Json::from(ok)),
            ("requests_per_sec", Json::from(rps)),
            ("infer_wall_ms", Json::from(infer_ms)),
            ("p50_ms", Json::from(p50)),
            ("p95_ms", Json::from(p95)),
            ("p99_ms", Json::from(p99)),
            ("os_threads", Json::from(os_threads)),
            ("server_threads", Json::from(server.thread_count())),
            ("per_core", Json::Arr(per_core)),
        ]));
        throughput.push((cores, rps));
        server.shutdown();
    }

    // Scaling assert: only meaningful when the host really has 4 cores
    // to run 4 shards on; oversubscribed tiers still ran above so the
    // JSON is complete either way.
    let tp = |c: usize| throughput.iter().find(|t| t.0 == c).map(|t| t.1);
    if let (Some(t1), Some(t4)) = (tp(1), tp(4)) {
        let speedup = t4 / t1.max(1e-9);
        println!("4-core speedup over 1 core: {speedup:.2}x (floor {min_scaling:.2}x)");
        if host_cores >= 4 {
            anyhow::ensure!(
                speedup >= min_scaling,
                "4-core throughput only {speedup:.2}x of 1-core (need {min_scaling:.2}x)"
            );
        } else {
            println!("host has {host_cores} cores; skipping the >= {min_scaling:.2}x assert");
        }
    }

    let out = Json::from_pairs(vec![
        ("bench", Json::from("session_scale")),
        ("sessions", Json::from(sessions)),
        ("workers_per_shard", Json::from(workers)),
        ("rounds", Json::from(rounds)),
        ("pp", Json::from(pp)),
        ("host_cores", Json::from(host_cores)),
        ("rows", Json::Arr(rows)),
    ]);
    write_bench_json("session_scale", &out)?;
    Ok(())
}
